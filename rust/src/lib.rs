// `--features simd` opts into the explicit f32x8 GEMM microkernel tier
// (tensor::{pack, microkernel}); portable_simd is nightly-only, so the
// gate keeps the default build on stable.
#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # grasswalk — Randomized Gradient Subspaces for Efficient LLM Training
//!
//! Production-grade reproduction of the paper's GrassWalk / GrassJump
//! optimizers and every substrate they need, as a three-layer Rust + JAX +
//! Pallas stack (Python only at build time; see DESIGN.md):
//!
//! * [`tensor`] — dense linalg substrate (GEMM, QR, SVD, randomized SVD)
//! * [`subspace`] — the basis lifecycle: providers (SVD / Haar / geodesic
//!   walk & track / shared-seed / coordinate), the unified refresh
//!   [`subspace::Schedule`], the per-matrix [`subspace::SubspaceEngine`],
//!   and Grassmannian geometry — shared by the optimizers and the comm
//!   collective
//! * [`optim`] — the paper's optimizer suite + baselines (GaLore, APOLLO,
//!   FRUGAL, LDAdam, SubTrack++, Fira, Adam, SGD) and the AO/RS components
//! * [`runtime`] — PJRT engine loading AOT HLO-text artifacts
//! * [`data`] — synthetic-C4 corpus, tokenizer, sharded prefetch loader
//! * [`model`] — LLaMA shape calculus, init, pure-Rust reference forward
//! * [`comm`] — collective-communication subsystem: persistent ring
//!   transport (in-process AND multi-host TCP rings with a local
//!   multi-process launcher), dense + subspace-compressed
//!   (error-feedback) all-reduce
//! * [`coordinator`] — trainer loop, grad accumulation, data-parallel
//!   workers with ring all-reduce, memory accountant, checkpoints
//! * [`metrics`] — time series recording + CSV/JSON emission, interned
//!   per-step push handles, and the crash-durable JSONL stream sink
//! * [`trace`] — step-phase runtime tracing: per-thread span rings,
//!   log2-histogram phase stats, per-rank summary gather, Chrome
//!   trace-event export
//! * [`analysis`] — gradient-subspace energy & curvature (Figures 1–2)
//! * [`config`] — TOML presets + typed experiment config
//! * [`util`] — in-repo substrates (RNG, pool, JSON, TOML, CLI, bench)
//!   plus the counting global allocator with tagged memory domains
//!   ([`util::alloc`]) behind the `--mem-diag` measured-memory story

pub mod ablation;
pub mod analysis;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod subspace;
pub mod tensor;
pub mod trace;
pub mod util;

// Bounded proof harnesses (rust/verify/) — compiled ONLY under
// `cargo kani`, invisible to the default build and tests. The #[path]
// hop keeps verification code out of src/ while placing it inside the
// crate, so harnesses can drive pub(crate) internals (wire::field,
// pool::RegionCounters, trace::ring's index helpers) instead of
// re-implementations. See EXPERIMENTS.md §Verify.
#[cfg(kani)]
#[path = "../verify/mod.rs"]
pub mod verify;
