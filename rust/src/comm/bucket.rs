//! Deterministic bucket partition of a [`GradLayout`] for overlapped
//! (pipelined) reduction.
//!
//! ## Bucket-determinism contract
//!
//! Bucket boundaries are a pure function of the layout geometry and the
//! configured `--bucket-kb` target — NEVER of timing, thread
//! interleaving, or which layers "finished backward first". Every rank
//! derives the identical [`BucketPlan`] locally (the layout fingerprint
//! is already pinned by the `comm::net` handshake), buckets are reduced
//! in ascending index order, and the per-bucket fold order inside the
//! transport is the same ring schedule as the single-shot path. That is
//! what lets the overlap pipeline change *when* wall-clock work happens
//! without changing a single bit of the result (pinned in
//! `rust/tests/comm_props.rs` / `net_props.rs`).
//!
//! Regions are never split across buckets: a bucket is a contiguous run
//! of whole [`GradRegion`]s, so the low-rank collective's per-region
//! factor packing and error-feedback residuals are untouched by
//! bucketing — only the granularity of the transport exchange changes.

use super::collective::{GradLayout, GradRegion};

/// One bucket: a contiguous run of whole regions, and the flat-vector
/// span they cover.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// First region index (into `GradLayout::regions`).
    pub first_region: usize,
    /// One past the last region index.
    pub end_region: usize,
    /// Start offset into the flat gradient vector.
    pub offset: usize,
    /// Dense float count covered.
    pub len: usize,
}

/// A fixed partition of the layout into reduction buckets, derived once
/// at trainer construction and reused every round.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    buckets: Vec<Bucket>,
}

/// Bucket indices ride the frame tag byte, so a plan never exceeds 255
/// buckets — the tail regions fold into the final bucket instead.
pub const MAX_BUCKETS: usize = 255;

impl BucketPlan {
    /// The trivial plan: everything in one bucket (what `bucket_kb = 0`
    /// means, and the shape under which the bucketed path defers to the
    /// legacy single-shot collective).
    pub fn single(layout: &GradLayout) -> BucketPlan {
        BucketPlan {
            buckets: vec![Bucket {
                first_region: 0,
                end_region: layout.regions.len(),
                offset: 0,
                len: layout.total_floats,
            }],
        }
    }

    /// Partition `layout` into buckets of roughly `bucket_kb` KiB of
    /// dense f32 payload each. Regions are taken in ABI order and never
    /// split; a bucket closes once it holds at least one region AND its
    /// dense bytes reach the target. `bucket_kb = 0` yields the single
    /// bucket.
    pub fn from_layout(layout: &GradLayout, bucket_kb: usize) -> BucketPlan {
        if bucket_kb == 0 || layout.regions.is_empty() {
            return BucketPlan::single(layout);
        }
        let _mem = crate::util::alloc::scope(
            crate::util::alloc::MemDomain::CommBuffers,
        );
        let target_floats = (bucket_kb * 1024) / 4;
        let mut buckets = Vec::new();
        let mut first = 0usize;
        let mut len = 0usize;
        for (i, r) in layout.regions.iter().enumerate() {
            len += r.len;
            let last = i + 1 == layout.regions.len();
            let full = len >= target_floats.max(1);
            let capped = buckets.len() + 1 >= MAX_BUCKETS;
            if last || (full && !capped) {
                buckets.push(Bucket {
                    first_region: first,
                    end_region: i + 1,
                    offset: layout.regions[first].offset,
                    len,
                });
                first = i + 1;
                len = 0;
            }
        }
        BucketPlan { buckets }
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    /// The regions of bucket `b`.
    pub fn regions<'a>(
        &self,
        layout: &'a GradLayout,
        b: usize,
    ) -> &'a [GradRegion] {
        let bk = &self.buckets[b];
        &layout.regions[bk.first_region..bk.end_region]
    }

    /// Low-rank packed floats bucket `b` puts on the wire at `rank`.
    pub fn packed_floats(
        &self,
        layout: &GradLayout,
        b: usize,
        rank: usize,
    ) -> usize {
        self.regions(layout, b)
            .iter()
            .map(|r| r.factor_floats(rank))
            .sum()
    }

    /// Largest dense bucket span — sizes the pipeline staging buffers.
    pub fn max_dense_floats(&self) -> usize {
        self.buckets.iter().map(|b| b.len).max().unwrap_or(0)
    }

    /// Largest packed bucket span at `rank`.
    pub fn max_packed_floats(&self, layout: &GradLayout, rank: usize) -> usize {
        (0..self.buckets.len())
            .map(|b| self.packed_floats(layout, b, rank))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> GradLayout {
        GradLayout::from_shapes(&[
            vec![64, 32],
            vec![32],
            vec![32, 48],
            vec![48],
            vec![8, 8],
        ])
    }

    #[test]
    fn single_plan_covers_everything() {
        let l = layout();
        let p = BucketPlan::single(&l);
        assert_eq!(p.len(), 1);
        assert_eq!(p.buckets()[0].offset, 0);
        assert_eq!(p.buckets()[0].len, l.total_floats);
        assert_eq!(p.regions(&l, 0).len(), l.regions.len());
    }

    #[test]
    fn zero_kb_means_single_bucket() {
        let l = layout();
        let p = BucketPlan::from_layout(&l, 0);
        assert_eq!(p.len(), 1);
        assert_eq!(p.buckets()[0].len, l.total_floats);
    }

    #[test]
    fn buckets_tile_the_flat_vector_without_splitting_regions() {
        let l = layout();
        for kb in [1, 2, 4, 7, 64, 10_000] {
            let p = BucketPlan::from_layout(&l, kb);
            let mut off = 0usize;
            let mut region = 0usize;
            for b in p.buckets() {
                assert_eq!(b.offset, off, "kb={kb}");
                assert_eq!(b.first_region, region, "kb={kb}");
                assert!(b.end_region > b.first_region, "kb={kb}");
                let span: usize = l.regions[b.first_region..b.end_region]
                    .iter()
                    .map(|r| r.len)
                    .sum();
                assert_eq!(b.len, span, "kb={kb}");
                off += b.len;
                region = b.end_region;
            }
            assert_eq!(off, l.total_floats, "kb={kb}");
            assert_eq!(region, l.regions.len(), "kb={kb}");
        }
    }

    #[test]
    fn plan_is_deterministic_and_timing_free() {
        let l = layout();
        let a = BucketPlan::from_layout(&l, 2);
        let b = BucketPlan::from_layout(&l, 2);
        assert_eq!(a.buckets(), b.buckets());
        // A 2 KiB target (512 floats) splits this ~4.7k-float layout.
        assert!(a.len() > 1);
        assert!(a.len() <= l.regions.len());
    }

    #[test]
    fn bucket_count_respects_the_tag_byte_cap() {
        let shapes: Vec<Vec<usize>> = (0..600).map(|_| vec![64]).collect();
        let l = GradLayout::from_shapes(&shapes);
        // 64 floats = 256 bytes < 1 KiB target: every region wants its
        // own bucket, but the plan must stay addressable by a u8 tag.
        let p = BucketPlan::from_layout(&l, 1);
        assert!(p.len() <= MAX_BUCKETS);
        let covered: usize = p.buckets().iter().map(|b| b.len).sum();
        assert_eq!(covered, l.total_floats);
    }

    #[test]
    fn packed_floats_match_layout_accounting() {
        let l = layout();
        let p = BucketPlan::from_layout(&l, 4);
        let rank = 16;
        let total: usize =
            (0..p.len()).map(|b| p.packed_floats(&l, b, rank)).sum();
        assert_eq!(total, l.packed_floats(rank));
        assert!(p.max_packed_floats(&l, rank) > 0);
        assert!(p.max_dense_floats() > 0);
    }
}
