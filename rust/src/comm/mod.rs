//! S14: the collective-communication subsystem.
//!
//! Four pillars, bottom-up:
//!
//! * [`transport`] — *how* payloads move: [`Transport`] with the
//!   persistent in-process [`RingTransport`] backend (N worker threads +
//!   N bounded neighbor links created once per trainer, reused every
//!   round).
//! * [`net`] — the multi-host backend: [`net::TcpRingTransport`] runs
//!   the SAME ring schedule over persistent TCP links between N
//!   processes (CRC-checked frames, handshake-validated worlds, a local
//!   `--spawn-local N` launcher), bitwise-identical to the in-process
//!   transport.
//! * [`collective`] — *what* is exchanged: [`Collective`] with
//!   [`DenseAllReduce`] (bitwise-equivalent to the legacy single-shot
//!   ring, bandwidth-optimal reduce-scatter/all-gather schedule and its
//!   traffic accounting) plus the flat-gradient [`GradLayout`] and the
//!   per-round [`CommStats`] the trainer records.
//! * [`lowrank`] — the paper-derived compressed variant:
//!   [`LowRankAllReduce`] exchanges rank-r factors against a shared-seed
//!   random basis regenerated locally on every worker (zero basis
//!   traffic — the [`crate::subspace::SharedSeedBasis`] provider, the
//!   same engine the optimizers draw from) with per-worker
//!   error-feedback residual accumulators, so
//!   the bulk gradient energy outside the core subspace is reinjected
//!   over subsequent rounds rather than lost.
//!
//! Two cross-cutting pieces ride on top (ISSUE 10):
//!
//! * [`bucket`] — a deterministic partition of the layout into
//!   reduction buckets ([`BucketPlan`], `--bucket-kb`). Boundaries are
//!   pure layout arithmetic — NEVER timing — so every rank derives the
//!   identical plan, and `--overlap` (a depth-2 begin/finish pipeline
//!   on the transport) changes only *when* wire time happens, never a
//!   bit of the result.
//! * [`codec`] — the `--wire f32|bf16|int8` quantized wire format for
//!   the low-rank factor exchange ([`WireCodec`]); quantization error
//!   folds into the existing error-feedback residuals, and `comm/bytes`
//!   reports true post-quantization wire traffic.
//!
//! The axes compose orthogonally: the trainer selects a comm regime
//! via [`CommMode`] (`--comm dense|lowrank`, `--comm-rank R`) and a
//! transport via [`TransportMode`] (`--transport inproc|tcp`, with
//! `--world N --net-rank k --peers …` for tcp); every combination
//! produces the same reduced gradients bit for bit. `--wire bf16|int8`
//! changes the transmitted values (still bitwise-reproducible across
//! transports and bucket plans) and requires `--comm lowrank`.

pub mod bucket;
pub mod codec;
pub mod collective;
pub mod lowrank;
pub mod net;
pub mod transport;

pub use bucket::{Bucket, BucketPlan};
pub use codec::WireCodec;
pub use collective::{
    Collective, CommStats, DenseAllReduce, GradLayout, GradRegion,
};
pub use lowrank::LowRankAllReduce;
pub use transport::{RingTransport, Transport, TransportStats};

/// The communication regime for the data-parallel gradient collective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommMode {
    /// Full-gradient ring all-reduce (bitwise ≡ the legacy path).
    Dense,
    /// Shared-seed rank-r factor exchange with error feedback.
    LowRank,
}

impl CommMode {
    pub fn label(&self) -> &'static str {
        match self {
            CommMode::Dense => "dense",
            CommMode::LowRank => "lowrank",
        }
    }

    pub fn parse(s: &str) -> Option<CommMode> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Some(CommMode::Dense),
            "lowrank" | "low-rank" => Some(CommMode::LowRank),
            _ => None,
        }
    }
}

/// Which [`Transport`] backend carries the collective
/// (`--transport inproc|tcp`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportMode {
    /// All worker endpoints simulated in this process (the default).
    Inproc,
    /// This process is one rank of a multi-process TCP ring
    /// (`--world N --net-rank k --peers host:port,…`).
    Tcp,
}

impl TransportMode {
    pub fn label(&self) -> &'static str {
        match self {
            TransportMode::Inproc => "inproc",
            TransportMode::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Option<TransportMode> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "local" => Some(TransportMode::Inproc),
            "tcp" => Some(TransportMode::Tcp),
            _ => None,
        }
    }
}

/// Wrap an already-established transport in the configured collective.
/// `rank`/`seed`/`codec` only matter for [`CommMode::LowRank`] (`--wire`
/// quantization applies to the factor exchange; the dense collective is
/// always exact f32).
pub fn build_collective_with(
    transport: Box<dyn Transport>,
    mode: CommMode,
    rank: usize,
    seed: u64,
    codec: WireCodec,
) -> Box<dyn Collective> {
    match mode {
        CommMode::Dense => Box::new(DenseAllReduce::new(transport)),
        CommMode::LowRank => Box::new(LowRankAllReduce::with_codec(
            transport,
            rank.max(1),
            seed,
            codec,
        )),
    }
}

/// Build the configured collective over a fresh persistent in-process
/// ring of `workers` endpoints, with the exact f32 wire codec.
/// `rank`/`seed` only matter for [`CommMode::LowRank`].
pub fn build_collective(
    mode: CommMode,
    workers: usize,
    rank: usize,
    seed: u64,
) -> Box<dyn Collective> {
    build_collective_with(
        Box::new(RingTransport::new(workers.max(1))),
        mode,
        rank,
        seed,
        WireCodec::F32,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [CommMode::Dense, CommMode::LowRank] {
            assert_eq!(CommMode::parse(m.label()), Some(m));
        }
        assert_eq!(CommMode::parse("low-rank"), Some(CommMode::LowRank));
        assert_eq!(CommMode::parse("nope"), None);
    }

    #[test]
    fn transport_mode_parse_roundtrip() {
        for m in [TransportMode::Inproc, TransportMode::Tcp] {
            assert_eq!(TransportMode::parse(m.label()), Some(m));
        }
        assert_eq!(TransportMode::parse("carrier-pigeon"), None);
    }

    #[test]
    fn builder_selects_implementation() {
        let d = build_collective(CommMode::Dense, 2, 8, 0);
        assert_eq!(d.label(), "dense");
        assert_eq!(d.transport().world_size(), 2);
        let l = build_collective(CommMode::LowRank, 2, 8, 0);
        assert_eq!(l.label(), "lowrank");
        assert_eq!(l.transport().local_endpoints(), 2);
    }

    #[test]
    fn builder_threads_the_wire_codec() {
        let q = build_collective_with(
            Box::new(RingTransport::new(2)),
            CommMode::LowRank,
            8,
            0,
            WireCodec::Int8,
        );
        assert_eq!(q.label(), "lowrank");
        // The dense collective ignores the codec (always exact f32).
        let d = build_collective_with(
            Box::new(RingTransport::new(2)),
            CommMode::Dense,
            8,
            0,
            WireCodec::Bf16,
        );
        assert_eq!(d.label(), "dense");
    }
}
