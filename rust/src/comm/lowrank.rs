//! Subspace-compressed all-reduce with error feedback.
//!
//! The paper's core observation — most gradient energy lives in a small
//! rank-r subspace while a non-trivial residual stays in the bulk —
//! applies to the data-parallel collective exactly as it does to
//! optimizer state. [`LowRankAllReduce`] exploits the part that makes it
//! free for communication: the random basis needs **zero traffic**,
//! because every worker regenerates the identical basis locally from a
//! shared seed — the subspace subsystem's
//! [`crate::subspace::SharedSeedBasis`] provider, the same sampler
//! GrassJump's subspace refresh uses.
//!
//! Per gradient matrix G (oriented long × short) and per round t:
//!
//!   1. every worker regenerates the shared Haar basis `P_t` (long × r);
//!   2. worker w forms `G'_w = G_w + E_w` (its error-feedback residual
//!      from prior rounds) and exchanges only the factor `F_w = P_tᵀ G'_w`
//!      (r × short instead of long × short);
//!   3. the factors are ring-all-reduced; every worker reconstructs the
//!      same mean gradient `P_t · mean(F_w)` locally;
//!   4. worker w keeps `E_w ← G'_w − P_t F_w` — the bulk energy it failed
//!      to transmit this round, reinjected into step 2 next round.
//!
//! Error feedback makes the scheme *lossless over time*: the identity
//! `mean(G_w) + mean(E_w_before) = reconstructed + mean(E_w_after)` holds
//! exactly (up to fp), and with Haar bases the untransmitted residual
//! contracts by ≈ (1 − r/long) per round — both pinned in
//! rust/tests/comm_props.rs. 1-D parameters (norms) are exchanged dense.

use anyhow::{anyhow, bail, Result};

use crate::subspace::SharedSeedBasis;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Mat};

use super::bucket::BucketPlan;
use super::codec::{decode_packed, encode_packed, WireCodec};
use super::collective::{Collective, CommStats, GradLayout, GradRegion};
use super::transport::Transport;

pub struct LowRankAllReduce {
    transport: Box<dyn Transport>,
    rank: usize,
    /// Wire codec for the factor exchange (`--wire f32|bf16|int8`).
    /// Quantized codecs switch the traffic from a ring all-reduce to a
    /// byte-block all-gather (quantized values don't sum on the wire);
    /// every rank dequantizes and folds the blocks in rank order, so
    /// the result stays bitwise-identical across transports AND across
    /// bucket plans. Quantization error is folded into the existing
    /// per-worker error-feedback residuals at pack time.
    codec: WireCodec,
    /// The shared-seed basis provider every worker regenerates from
    /// locally (the subspace engine's recipe; zero basis traffic).
    basis: SharedSeedBasis,
    /// Round counter — part of the shared basis derivation, so the basis
    /// walks every round without any coordination traffic. Re-aligned to
    /// the trainer step on checkpoint restore ([`Collective::set_round`]).
    round: u64,
    /// Per-worker, per-region error-feedback residuals (empty 0×0 mats
    /// for 1-D regions; lazily sized on the first round). Deliberately
    /// NOT checkpointed — like optimizer subspace state, they are
    /// transient deferred energy; a restore drops at most one round's
    /// untransmitted bulk.
    residuals: Vec<Vec<Mat>>,
    /// Reusable scratch (per-worker wire buffers + pack/reconstruct
    /// intermediates): steady-state rounds do no heap allocation here —
    /// only the shared-basis regeneration (QR of a fresh gaussian, the
    /// scheme's defining cost) allocates.
    packed: Vec<Vec<f32>>,
    g: Mat,
    factor: Mat,
    recon: Mat,
    /// World-sized quantized byte blocks in rank order, ping-ponged
    /// through the transport's byte gather.
    blocks: Vec<Vec<u8>>,
    /// Per-region quantize→dequantize byte scratch (folding codec
    /// error into error feedback at pack time).
    qbytes: Vec<u8>,
    /// Decode scratch (per block / per region round-trip).
    dequant: Vec<f32>,
    /// Rank-order fold of the wire view: the dequantized-block sum on
    /// the quantized path, the per-bucket reduced factors on the
    /// bucketed f32 path.
    wire_sum: Vec<f32>,
    /// Pooled staging shells for the bucketed pipeline.
    shells: std::collections::VecDeque<Vec<Vec<f32>>>,
    gshells: std::collections::VecDeque<Vec<Vec<u8>>>,
    /// Begin timestamps of in-flight buckets (FIFO).
    inflight: std::collections::VecDeque<std::time::Instant>,
}

impl LowRankAllReduce {
    pub fn new(
        transport: Box<dyn Transport>,
        rank: usize,
        seed: u64,
    ) -> LowRankAllReduce {
        LowRankAllReduce::with_codec(transport, rank, seed, WireCodec::F32)
    }

    pub fn with_codec(
        transport: Box<dyn Transport>,
        rank: usize,
        seed: u64,
        codec: WireCodec,
    ) -> LowRankAllReduce {
        assert!(rank >= 1);
        LowRankAllReduce {
            transport,
            rank,
            codec,
            basis: SharedSeedBasis { seed },
            round: 0,
            residuals: Vec::new(),
            packed: Vec::new(),
            g: Mat::default(),
            factor: Mat::default(),
            recon: Mat::default(),
            blocks: Vec::new(),
            qbytes: Vec::new(),
            dequant: Vec::new(),
            wire_sum: Vec::new(),
            shells: std::collections::VecDeque::with_capacity(2),
            gshells: std::collections::VecDeque::with_capacity(2),
            inflight: std::collections::VecDeque::with_capacity(2),
        }
    }

    pub fn codec(&self) -> WireCodec {
        self.codec
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Rounds completed so far (= the round index the *next* call will
    /// derive its bases from is `rounds_done()`).
    pub fn rounds_done(&self) -> u64 {
        self.round
    }

    /// Test/diagnostic access to a worker's residual accumulator.
    pub fn residual(&self, worker: usize, region: usize) -> Option<&Mat> {
        self.residuals.get(worker)?.get(region)
    }

    /// The shared basis for `region` at round `round` of this collective
    /// (what every worker regenerates locally) — delegated to the
    /// subspace subsystem's shared-seed provider. Exposed so tests and
    /// the analysis tooling can reproduce the exact wire view.
    pub fn basis_for(&self, round: u64, region: usize, long: usize) -> Mat {
        self.basis.at(round, region as u64, long, self.rank)
    }
}

/// Pack one region of one worker's gradient: the factor projection for
/// matrices (with the codec's quantize→dequantize round-trip folded in,
/// so error feedback charges EXACTLY what peers will decode), a raw
/// copy for 1-D tails. Appends the wire-view floats to `out` and
/// updates the region's residual in place.
// hot-path
#[allow(clippy::too_many_arguments)]
fn pack_region(
    codec: WireCodec,
    rank: usize,
    reg: &GradRegion,
    basis: &Mat,
    slice: &[f32],
    residual: &mut Mat,
    g: &mut Mat,
    factor: &mut Mat,
    recon: &mut Mat,
    qbytes: &mut Vec<u8>,
    qfloats: &mut Vec<f32>,
    out: &mut Vec<f32>,
) -> Result<()> {
    if !reg.is_matrix() {
        out.extend_from_slice(slice);
        return Ok(());
    }
    g.resize_to(reg.rows, reg.cols);
    g.data.copy_from_slice(slice);
    g.axpy(1.0, residual); // G' = G + E
    if reg.rows >= reg.cols {
        matmul_tn_into(basis, g, factor); // r × cols
    } else {
        matmul_into(g, basis, factor); // rows × r
    }
    if codec != WireCodec::F32 {
        // The wire carries quant(F); replace the factor with its exact
        // round-trip so the reconstruction, the residual, AND the bytes
        // we put on the wire all agree. Every rank (sender included)
        // decodes from the gathered blocks, so cross-rank bitwise
        // equality never depends on re-encode idempotency; bf16
        // re-encodes to identical bytes, and int8 keeps its i8 payload
        // stable (a scale byte can drift one ulp on a rounding tie,
        // which the next round's error feedback absorbs).
        encode_packed(codec, std::slice::from_ref(reg), rank, &factor.data, qbytes);
        decode_packed(codec, std::slice::from_ref(reg), rank, qbytes, qfloats)
            .map_err(|e| anyhow!("lowrank codec round-trip: {e}"))?;
        factor.data.copy_from_slice(qfloats);
    }
    if reg.rows >= reg.cols {
        matmul_into(basis, factor, recon);
    } else {
        matmul_nt_into(factor, basis, recon);
    }
    // Error feedback in place: E ← G' − transmitted.
    residual.assign_zip(g, recon, |a, b| a - b);
    out.extend_from_slice(&factor.data);
    Ok(())
}

/// Expand the mean packed vector back to the dense layout, identically
/// into every local worker buffer.
// hot-path
fn reconstruct_mean(
    layout: &GradLayout,
    rank: usize,
    bases: &[Mat],
    mean: &[f32],
    workers: &mut [Vec<f32>],
    factor: &mut Mat,
    recon: &mut Mat,
) {
    let Some((first, rest)) = workers.split_first_mut() else {
        return;
    };
    let mut poff = 0usize;
    for (k, reg) in layout.regions.iter().enumerate() {
        let fl = reg.factor_floats(rank);
        let src = &mean[poff..poff + fl];
        let dst = &mut first[reg.offset..reg.offset + reg.len];
        if reg.is_matrix() {
            let basis = &bases[k];
            if reg.rows >= reg.cols {
                factor.resize_to(basis.cols, reg.cols);
                factor.data.copy_from_slice(src);
                matmul_into(basis, factor, recon);
            } else {
                factor.resize_to(reg.rows, basis.cols);
                factor.data.copy_from_slice(src);
                matmul_nt_into(factor, basis, recon);
            }
            dst.copy_from_slice(&recon.data);
        } else {
            dst.copy_from_slice(src);
        }
        poff += fl;
    }
    for w in rest.iter_mut() {
        w.copy_from_slice(first);
    }
}

impl Collective for LowRankAllReduce {
    fn label(&self) -> &'static str {
        "lowrank"
    }

    fn transport(&self) -> &dyn Transport {
        &*self.transport
    }

    fn set_round(&mut self, round: u64) {
        self.round = round;
        // A restore abandons the current trajectory: stale deferred
        // energy from it must not leak into the resumed run's gradients.
        // Residuals re-initialize to zero on the next round.
        self.residuals.clear();
    }

    fn all_reduce_mean(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
    ) -> Result<CommStats> {
        let n = self.transport.world_size();
        let local = self.transport.local_endpoints();
        if workers.len() != local {
            bail!(
                "lowrank collective: {} buffers for {local} local \
                 endpoints (world {n})",
                workers.len()
            );
        }
        if workers.iter().any(|w| w.len() != layout.total_floats) {
            bail!(
                "lowrank collective: buffer length != layout total {}",
                layout.total_floats
            );
        }
        let packed_len = layout.packed_floats(self.rank);
        let dense = layout.total_floats;
        let compression = dense as f64 / packed_len.max(1) as f64;
        if n == 1 {
            // Nothing crosses a wire with one worker: pass the gradient
            // through untouched (no deferral via error feedback either),
            // keeping --comm lowrank ≡ dense at world size 1.
            return Ok(CommStats {
                bytes_per_worker: 0,
                payload_floats: packed_len,
                dense_floats: dense,
                compression,
                residual_norm: 0.0,
                hops: 0,
                overlap_flight_ns: 0,
                overlap_wait_ns: 0,
            });
        }

        // One-time buffer growth below (residual accumulators, packed
        // wire buffers) lands in the CommBuffers memory domain; the
        // steady-state round allocates nothing, so the scope guard is
        // the only per-round cost (two TLS writes).
        let _mem = crate::util::alloc::scope(
            crate::util::alloc::MemDomain::CommBuffers,
        );
        if self.residuals.is_empty() {
            self.residuals = (0..local)
                .map(|_| {
                    layout
                        .regions
                        .iter()
                        .map(|reg| {
                            if reg.is_matrix() {
                                Mat::zeros(reg.rows, reg.cols)
                            } else {
                                Mat::default()
                            }
                        })
                        .collect()
                })
                .collect();
        }

        // Shared bases for this round — identical on every worker by
        // construction, so they never touch the transport.
        let round = self.round;
        let bases: Vec<Mat> = layout
            .regions
            .iter()
            .enumerate()
            .map(|(k, reg)| {
                if reg.is_matrix() {
                    let (long, _) = reg.oriented();
                    self.basis_for(round, k, long)
                } else {
                    Mat::default()
                }
            })
            .collect();

        // Split field borrows: scratch, residuals and the transport are
        // used side by side below.
        let rank = self.rank;
        let codec = self.codec;
        let quantized = codec != WireCodec::F32;
        let Self {
            transport,
            residuals,
            packed,
            g,
            factor,
            recon,
            blocks,
            qbytes,
            dequant,
            wire_sum,
            ..
        } = self;

        // ---- pack: per worker, factors for matrices + raw 1-D tails ----
        // All intermediates live in the owned scratch; steady-state
        // rounds allocate nothing on this path.
        if packed.len() != local {
            *packed =
                (0..local).map(|_| Vec::with_capacity(packed_len)).collect();
        }
        for (w, buf) in workers.iter().enumerate() {
            let p = &mut packed[w];
            p.clear();
            for (k, reg) in layout.regions.iter().enumerate() {
                let slice = &buf[reg.offset..reg.offset + reg.len];
                pack_region(
                    codec,
                    rank,
                    reg,
                    &bases[k],
                    slice,
                    &mut residuals[w][k],
                    g,
                    factor,
                    recon,
                    qbytes,
                    dequant,
                    p,
                )?;
            }
            debug_assert_eq!(p.len(), packed_len);
        }

        // ---- the only traffic ----
        let (bytes_per_worker, hops, own_wire_bytes);
        if !quantized {
            // f32: ring all-reduce over the packed factors.
            let tstats = transport.all_reduce_sum(packed)?;
            bytes_per_worker = tstats.bytes_sent_per_worker;
            hops = tstats.hops;
            own_wire_bytes = packed_len * 4;
        } else {
            // Quantized: values don't sum on the wire, so each rank
            // encodes its LOCAL workers' factors into their world
            // slots, all-gathers the byte blocks, and folds ALL blocks
            // in rank order locally — a deterministic sum independent
            // of transport and bucketing.
            if blocks.len() != n {
                blocks.resize_with(n, Vec::new);
            }
            let off = transport.rank_offset();
            for (w, p) in packed.iter().enumerate() {
                encode_packed(
                    codec,
                    &layout.regions,
                    rank,
                    p,
                    &mut blocks[off + w],
                );
            }
            let sent = transport.all_gather_bytes(blocks, codec.tag())?;
            own_wire_bytes = blocks[off].len();
            wire_sum.clear();
            wire_sum.resize(packed_len, 0.0);
            for b in blocks.iter() {
                decode_packed(codec, &layout.regions, rank, b, dequant)
                    .map_err(|e| anyhow!("lowrank decode: {e}"))?;
                for (s, d) in wire_sum.iter_mut().zip(dequant.iter()) {
                    *s += *d;
                }
            }
            bytes_per_worker = sent;
            hops = n - 1;
        }

        // ---- mean + local reconstruction (identical on every worker) ---
        let inv = 1.0 / n as f32;
        {
            let m: &mut Vec<f32> =
                if quantized { wire_sum } else { &mut packed[0] };
            for x in m.iter_mut() {
                *x *= inv;
            }
        }
        let mean: &[f32] = if quantized { wire_sum } else { &packed[0] };
        reconstruct_mean(layout, rank, &bases, mean, workers, factor, recon);

        // Mean over the residual accumulators living in THIS process:
        // all n workers for the in-process transport, just our own rank's
        // for a socket backend (residuals are per-worker local state that
        // never crosses the wire).
        let residual_norm = residuals
            .iter()
            .map(|per_region| {
                per_region
                    .iter()
                    .map(|e| e.fro_norm_sq())
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / local as f64;

        self.round += 1;
        // Quantized compression is measured in BYTES against the dense
        // f32 wire (4·dense), since the payload is no longer floats.
        let compression = if quantized {
            (dense * 4) as f64 / own_wire_bytes.max(1) as f64
        } else {
            compression
        };
        Ok(CommStats {
            bytes_per_worker,
            payload_floats: packed_len,
            dense_floats: dense,
            compression,
            residual_norm,
            hops,
            overlap_flight_ns: 0,
            overlap_wait_ns: 0,
        })
    }

    /// Depth-2 bucket pipeline over the factor exchange. The basis
    /// round, per-region packing, and error feedback are untouched by
    /// bucketing (regions are never split); only the transport
    /// granularity changes. Overlap-on ≡ overlap-off bitwise for a
    /// fixed plan, and the quantized path is additionally bitwise
    /// identical to its single-shot form for ANY world size (the fold
    /// is always the rank-ordered block sum).
    // hot-path
    fn all_reduce_mean_bucketed(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
        plan: &BucketPlan,
        overlap: bool,
    ) -> Result<CommStats> {
        if plan.len() <= 1 || self.transport.world_size() == 1 {
            return self.all_reduce_mean(workers, layout);
        }
        let n = self.transport.world_size();
        let local = self.transport.local_endpoints();
        if workers.len() != local {
            bail!(
                "lowrank collective: {} buffers for {local} local \
                 endpoints (world {n})",
                workers.len()
            );
        }
        if workers.iter().any(|w| w.len() != layout.total_floats) {
            bail!(
                "lowrank collective: buffer length != layout total {}",
                layout.total_floats
            );
        }
        let packed_len = layout.packed_floats(self.rank);
        let dense = layout.total_floats;

        let _mem = crate::util::alloc::scope(
            crate::util::alloc::MemDomain::CommBuffers,
        );
        if self.residuals.is_empty() {
            self.residuals = (0..local)
                .map(|_| {
                    layout
                        .regions
                        .iter()
                        .map(|reg| {
                            if reg.is_matrix() {
                                Mat::zeros(reg.rows, reg.cols)
                            } else {
                                Mat::default()
                            }
                        })
                        .collect()
                })
                .collect();
        }
        let round = self.round;
        let bases: Vec<Mat> = layout
            .regions
            .iter()
            .enumerate()
            .map(|(k, reg)| {
                if reg.is_matrix() {
                    let (long, _) = reg.oriented();
                    self.basis_for(round, k, long)
                } else {
                    Mat::default()
                }
            })
            .collect();

        let rank = self.rank;
        let codec = self.codec;
        let quantized = codec != WireCodec::F32;
        let Self {
            transport,
            residuals,
            packed,
            g,
            factor,
            recon,
            qbytes,
            dequant,
            wire_sum,
            shells,
            gshells,
            inflight,
            ..
        } = self;
        let overlap = overlap && transport.supports_overlap();
        let off = transport.rank_offset();
        if packed.len() != local {
            *packed =
                (0..local).map(|_| Vec::with_capacity(packed_len)).collect();
        }
        wire_sum.clear();
        wire_sum.resize(packed_len, 0.0);
        let nb = plan.len();
        let mut bytes = 0usize;
        let mut hops = 0usize;
        let mut flight_ns = 0u64;
        let mut wait_ns = 0u64;
        let mut own_wire_bytes = 0usize;
        // Finishes land FIFO in ascending bucket order; this running
        // offset places each bucket's factors in the packed vector.
        let mut fin_poff = 0usize;

        macro_rules! begin_bucket {
            ($b:expr) => {{
                let b: usize = $b;
                let sp = crate::trace::start();
                let bk = plan.buckets()[b];
                if quantized {
                    let mut gb = gshells.pop_front().unwrap_or_default();
                    while gb.len() < n {
                        gb.push(Vec::with_capacity(64));
                    }
                    gb.truncate(n);
                    for blk in gb.iter_mut() {
                        blk.clear();
                    }
                    for (w, buf) in workers.iter().enumerate() {
                        let p = &mut packed[w];
                        p.clear();
                        for k in bk.first_region..bk.end_region {
                            let reg = &layout.regions[k];
                            pack_region(
                                codec,
                                rank,
                                reg,
                                &bases[k],
                                &buf[reg.offset..reg.offset + reg.len],
                                &mut residuals[w][k],
                                g,
                                factor,
                                recon,
                                qbytes,
                                dequant,
                                p,
                            )?;
                        }
                        encode_packed(
                            codec,
                            plan.regions(layout, b),
                            rank,
                            p,
                            &mut gb[off + w],
                        );
                    }
                    inflight.push_back(std::time::Instant::now());
                    transport.gather_bytes_begin(gb, codec.tag())?;
                } else {
                    let mut shell = shells.pop_front().unwrap_or_default();
                    while shell.len() < local {
                        shell.push(Vec::with_capacity(64));
                    }
                    shell.truncate(local);
                    for (w, buf) in workers.iter().enumerate() {
                        let p = &mut shell[w];
                        p.clear();
                        for k in bk.first_region..bk.end_region {
                            let reg = &layout.regions[k];
                            pack_region(
                                codec,
                                rank,
                                reg,
                                &bases[k],
                                &buf[reg.offset..reg.offset + reg.len],
                                &mut residuals[w][k],
                                g,
                                factor,
                                recon,
                                qbytes,
                                dequant,
                                p,
                            )?;
                        }
                    }
                    inflight.push_back(std::time::Instant::now());
                    transport.reduce_begin(shell, b as u8)?;
                }
                sp.record(crate::trace::Phase::BucketReduce);
            }};
        }
        macro_rules! finish_bucket {
            ($b:expr) => {{
                let b: usize = $b;
                let sp = crate::trace::start();
                let fl = plan.packed_floats(layout, b, rank);
                let waited = std::time::Instant::now();
                if quantized {
                    let (gb, sent) = transport.gather_bytes_finish()?;
                    // The overlap clock only runs when buckets are
                    // actually pipelined: a serial round's wait IS its
                    // flight, and recording it would pollute the
                    // `comm/overlap_ratio` series with trivial zeros.
                    if overlap {
                        wait_ns += waited.elapsed().as_nanos() as u64;
                        flight_ns += inflight
                            .front()
                            .map(|t| t.elapsed().as_nanos() as u64)
                            .unwrap_or(0);
                    }
                    inflight.pop_front();
                    let regs = plan.regions(layout, b);
                    let span = &mut wire_sum[fin_poff..fin_poff + fl];
                    for blk in gb.iter() {
                        decode_packed(codec, regs, rank, blk, dequant)
                            .map_err(|e| {
                                anyhow!("lowrank bucket {b} decode: {e}")
                            })?;
                        for (s, d) in span.iter_mut().zip(dequant.iter()) {
                            *s += *d;
                        }
                    }
                    own_wire_bytes += gb[off].len();
                    bytes += sent;
                    hops += n - 1;
                    gshells.push_back(gb);
                } else {
                    let (shell, tstats) = transport.reduce_finish()?;
                    if overlap {
                        wait_ns += waited.elapsed().as_nanos() as u64;
                        flight_ns += inflight
                            .front()
                            .map(|t| t.elapsed().as_nanos() as u64)
                            .unwrap_or(0);
                    }
                    inflight.pop_front();
                    wire_sum[fin_poff..fin_poff + fl]
                        .copy_from_slice(&shell[0]);
                    bytes += tstats.bytes_sent_per_worker;
                    hops += tstats.hops;
                    own_wire_bytes += fl * 4;
                    shells.push_back(shell);
                }
                fin_poff += fl;
                sp.record(crate::trace::Phase::BucketReduce);
            }};
        }

        if overlap {
            begin_bucket!(0);
            for b in 1..nb {
                begin_bucket!(b);
                finish_bucket!(b - 1);
            }
            finish_bucket!(nb - 1);
        } else {
            for b in 0..nb {
                begin_bucket!(b);
                finish_bucket!(b);
            }
        }
        debug_assert_eq!(fin_poff, packed_len);

        // ---- mean + local reconstruction ----
        let inv = 1.0 / n as f32;
        for x in wire_sum.iter_mut() {
            *x *= inv;
        }
        reconstruct_mean(layout, rank, &bases, wire_sum, workers, factor, recon);

        let residual_norm = residuals
            .iter()
            .map(|per_region| {
                per_region
                    .iter()
                    .map(|e| e.fro_norm_sq())
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / local as f64;

        self.round += 1;
        let compression = if quantized {
            (dense * 4) as f64 / own_wire_bytes.max(1) as f64
        } else {
            dense as f64 / packed_len.max(1) as f64
        };
        Ok(CommStats {
            bytes_per_worker: bytes,
            payload_floats: packed_len,
            dense_floats: dense,
            compression,
            residual_norm,
            hops,
            overlap_flight_ns: flight_ns,
            overlap_wait_ns: wait_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::RingTransport;
    use crate::util::rng::Rng;

    fn layout() -> GradLayout {
        // Tall matrix, wide matrix, and a 1-D tail.
        GradLayout::from_shapes(&[vec![10, 6], vec![5, 12], vec![7]])
    }

    fn rand_workers(n: usize, total: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; total];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn all_workers_get_identical_reconstruction() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(3)),
            4,
            11,
        );
        let mut bufs = rand_workers(3, layout.total_floats, 1);
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(bufs[0], bufs[1]);
        assert_eq!(bufs[0], bufs[2]);
    }

    #[test]
    fn dense_tail_is_exact_mean() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            5,
        );
        let mut bufs = rand_workers(2, layout.total_floats, 2);
        let tail = layout.regions[2];
        let expect: Vec<f32> = (0..tail.len)
            .map(|i| {
                (bufs[0][tail.offset + i] + bufs[1][tail.offset + i]) / 2.0
            })
            .collect();
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        for (i, &want) in expect.iter().enumerate() {
            let got = bufs[0][tail.offset + i];
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn single_worker_is_passthrough() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(1)),
            4,
            5,
        );
        let mut bufs = rand_workers(1, layout.total_floats, 3);
        let before = bufs[0].clone();
        let stats = c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(bufs[0], before);
        assert_eq!(stats.bytes_per_worker, 0);
        assert!(stats.compression > 1.0);
    }

    #[test]
    fn set_round_realigns_basis_schedule() {
        // A fresh collective fast-forwarded with set_round(3) must be
        // bitwise-equivalent to one that already ran 3 rounds with zero
        // gradients (zero input leaves residuals at zero, isolating the
        // schedule) — the checkpoint-restore contract.
        let layout = layout();
        let mk = || {
            LowRankAllReduce::new(Box::new(RingTransport::new(2)), 4, 5)
        };
        let mut advanced = mk();
        for _ in 0..3 {
            let mut z: Vec<Vec<f32>> =
                (0..2).map(|_| vec![0.0f32; layout.total_floats]).collect();
            advanced.all_reduce_mean(&mut z, &layout).unwrap();
        }
        let mut restored = mk();
        restored.set_round(3);
        assert_eq!(restored.rounds_done(), 3);
        let bufs = rand_workers(2, layout.total_floats, 17);
        let mut x = bufs.clone();
        let mut y = bufs.clone();
        advanced.all_reduce_mean(&mut x, &layout).unwrap();
        restored.all_reduce_mean(&mut y, &layout).unwrap();
        assert_eq!(x[0], y[0], "restored schedule must match continuous");
        // Without realignment the basis (hence the output) differs.
        let mut fresh = mk();
        let mut w = bufs;
        fresh.all_reduce_mean(&mut w, &layout).unwrap();
        assert_ne!(x[0], w[0]);
    }

    #[test]
    fn set_round_clears_stale_residuals() {
        // Restoring into an already-run collective must not leak the
        // abandoned trajectory's deferred energy into the resumed run.
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            5,
        );
        let mut bufs = rand_workers(2, layout.total_floats, 9);
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert!(
            c.residual(0, 0).map(|e| e.fro_norm() > 0.0).unwrap_or(false),
            "round with real gradients must leave a residual"
        );
        c.set_round(0);
        assert!(
            c.residual(0, 0).is_none(),
            "restore must drop stale deferred energy"
        );
        // And the collective keeps working after the reset.
        let mut bufs = rand_workers(2, layout.total_floats, 10);
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(bufs[0], bufs[1]);
    }

    #[test]
    fn steady_state_rounds_reuse_scratch() {
        // Many rounds on one collective must keep working with the
        // reusable scratch (shape cycling across regions included).
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            8,
        );
        for seed in 0..10 {
            let mut bufs = rand_workers(2, layout.total_floats, 200 + seed);
            let stats = c.all_reduce_mean(&mut bufs, &layout).unwrap();
            assert_eq!(stats.payload_floats, layout.packed_floats(4));
            assert_eq!(bufs[0], bufs[1]);
        }
        assert_eq!(c.rounds_done(), 10);
    }

    #[test]
    fn rejects_bad_inputs() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            5,
        );
        let mut wrong_world = rand_workers(1, layout.total_floats, 4);
        assert!(c.all_reduce_mean(&mut wrong_world, &layout).is_err());
        let mut wrong_len = vec![vec![0.0f32; 3], vec![0.0f32; 3]];
        assert!(c.all_reduce_mean(&mut wrong_len, &layout).is_err());
    }

    #[test]
    fn bucketed_overlap_matches_serial_and_single_shot() {
        // Three collectives, identical seeds: single-shot, bucketed
        // serial, bucketed overlapped. At world 2 every f32 chunk sum
        // has exactly two terms, so all three must agree BITWISE over
        // rounds that carry live EF residuals across a refresh.
        let layout = layout();
        let plan = BucketPlan::from_layout(&layout, 1);
        assert!(plan.len() > 1, "1 KiB target must split this layout");
        let mk = || {
            LowRankAllReduce::new(Box::new(RingTransport::new(2)), 4, 5)
        };
        let (mut single, mut serial, mut overlap) = (mk(), mk(), mk());
        for round in 0..4 {
            let bufs = rand_workers(2, layout.total_floats, 40 + round);
            let (mut a, mut b, mut c) =
                (bufs.clone(), bufs.clone(), bufs);
            single.all_reduce_mean(&mut a, &layout).unwrap();
            let sb = serial
                .all_reduce_mean_bucketed(&mut b, &layout, &plan, false)
                .unwrap();
            let ob = overlap
                .all_reduce_mean_bucketed(&mut c, &layout, &plan, true)
                .unwrap();
            assert_eq!(a, b, "round {round}: bucketed-serial differs");
            assert_eq!(a, c, "round {round}: bucketed-overlap differs");
            assert_eq!(sb.bytes_per_worker, ob.bytes_per_worker);
            assert_eq!(sb.overlap_flight_ns, 0, "serial path never waits");
            assert!(
                ob.overlap_flight_ns > 0,
                "overlap path must report in-flight time"
            );
        }
    }

    #[test]
    fn quantized_bucketed_matches_single_shot_bitwise() {
        // The quantized fold is a rank-ordered block sum — independent
        // of the bucket plan and of overlap — so bf16/int8 bucketed
        // rounds must match the single-shot path bitwise at ANY world
        // size (here 3, where the f32 ring would NOT be order-free).
        let layout = layout();
        let plan = BucketPlan::from_layout(&layout, 1);
        assert!(plan.len() > 1);
        for codec in [WireCodec::Bf16, WireCodec::Int8] {
            let mk = || {
                LowRankAllReduce::with_codec(
                    Box::new(RingTransport::new(3)),
                    4,
                    5,
                    codec,
                )
            };
            let (mut single, mut bucketed) = (mk(), mk());
            for round in 0..4 {
                let bufs =
                    rand_workers(3, layout.total_floats, 80 + round);
                let (mut a, mut b) = (bufs.clone(), bufs);
                single.all_reduce_mean(&mut a, &layout).unwrap();
                bucketed
                    .all_reduce_mean_bucketed(
                        &mut b, &layout, &plan, true,
                    )
                    .unwrap();
                assert_eq!(
                    a,
                    b,
                    "{} round {round}: quantized bucketed differs \
                     from single-shot",
                    codec.label()
                );
            }
        }
    }

    #[test]
    fn quantized_workers_agree_and_compress_harder() {
        // Every worker reconstructs the identical mean from the shared
        // gathered blocks, the EF residual absorbs the quantization
        // error (non-zero residual), and the recorded compression
        // beats the exact-f32 factor exchange.
        let layout = layout();
        let f32_stats = {
            let mut c = LowRankAllReduce::new(
                Box::new(RingTransport::new(2)),
                4,
                5,
            );
            let mut bufs = rand_workers(2, layout.total_floats, 21);
            c.all_reduce_mean(&mut bufs, &layout).unwrap()
        };
        for codec in [WireCodec::Bf16, WireCodec::Int8] {
            let mut c = LowRankAllReduce::with_codec(
                Box::new(RingTransport::new(2)),
                4,
                5,
                codec,
            );
            let mut bufs = rand_workers(2, layout.total_floats, 21);
            let stats = c.all_reduce_mean(&mut bufs, &layout).unwrap();
            assert_eq!(bufs[0], bufs[1], "{}", codec.label());
            assert!(
                stats.residual_norm > 0.0,
                "{}: EF must hold the quantization error",
                codec.label()
            );
            assert!(
                stats.compression > f32_stats.compression,
                "{}: quantized wire must compress harder than f32 \
                 ({} vs {})",
                codec.label(),
                stats.compression,
                f32_stats.compression
            );
        }
    }

    #[test]
    fn quantization_error_drains_through_error_feedback() {
        // A CONSTANT gradient fed repeatedly: with EF, the quantized
        // mean must converge toward the exact mean (deferred energy is
        // reinjected, not lost). Compare the last round's
        // reconstruction error against the first round's.
        let layout = layout();
        let mut c = LowRankAllReduce::with_codec(
            Box::new(RingTransport::new(2)),
            6,
            5,
            WireCodec::Int8,
        );
        let fixed = rand_workers(2, layout.total_floats, 33);
        let exact: Vec<f32> = (0..layout.total_floats)
            .map(|i| (fixed[0][i] + fixed[1][i]) / 2.0)
            .collect();
        let reg = layout.regions[0]; // a projected matrix region
        let err = |got: &[f32]| -> f64 {
            (0..reg.len)
                .map(|i| {
                    let d = (got[reg.offset + i]
                        - exact[reg.offset + i])
                        as f64;
                    d * d
                })
                .sum::<f64>()
                .sqrt()
        };
        let mut first = None;
        let mut last = 0.0f64;
        let mut cumulative = vec![0.0f64; layout.total_floats];
        for round in 0..24 {
            let mut bufs = fixed.clone();
            c.all_reduce_mean(&mut bufs, &layout).unwrap();
            for (acc, &g) in cumulative.iter_mut().zip(&bufs[0]) {
                *acc += g as f64;
            }
            // The running average of delivered means is what training
            // integrates; EF should push it toward the exact mean.
            let avg: Vec<f32> = cumulative
                .iter()
                .map(|a| (*a / (round + 1) as f64) as f32)
                .collect();
            last = err(&avg);
            if round == 0 {
                first = Some(last);
            }
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.5,
            "EF must drain quantization error over rounds: first \
             {first} last {last}"
        );
    }
}
