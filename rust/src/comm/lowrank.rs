//! Subspace-compressed all-reduce with error feedback.
//!
//! The paper's core observation — most gradient energy lives in a small
//! rank-r subspace while a non-trivial residual stays in the bulk —
//! applies to the data-parallel collective exactly as it does to
//! optimizer state. [`LowRankAllReduce`] exploits the part that makes it
//! free for communication: the random basis needs **zero traffic**,
//! because every worker regenerates the identical basis locally from a
//! shared seed — the subspace subsystem's
//! [`crate::subspace::SharedSeedBasis`] provider, the same sampler
//! GrassJump's subspace refresh uses.
//!
//! Per gradient matrix G (oriented long × short) and per round t:
//!
//!   1. every worker regenerates the shared Haar basis `P_t` (long × r);
//!   2. worker w forms `G'_w = G_w + E_w` (its error-feedback residual
//!      from prior rounds) and exchanges only the factor `F_w = P_tᵀ G'_w`
//!      (r × short instead of long × short);
//!   3. the factors are ring-all-reduced; every worker reconstructs the
//!      same mean gradient `P_t · mean(F_w)` locally;
//!   4. worker w keeps `E_w ← G'_w − P_t F_w` — the bulk energy it failed
//!      to transmit this round, reinjected into step 2 next round.
//!
//! Error feedback makes the scheme *lossless over time*: the identity
//! `mean(G_w) + mean(E_w_before) = reconstructed + mean(E_w_after)` holds
//! exactly (up to fp), and with Haar bases the untransmitted residual
//! contracts by ≈ (1 − r/long) per round — both pinned in
//! rust/tests/comm_props.rs. 1-D parameters (norms) are exchanged dense.

use anyhow::{bail, Result};

use crate::subspace::SharedSeedBasis;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Mat};

use super::collective::{Collective, CommStats, GradLayout};
use super::transport::Transport;

pub struct LowRankAllReduce {
    transport: Box<dyn Transport>,
    rank: usize,
    /// The shared-seed basis provider every worker regenerates from
    /// locally (the subspace engine's recipe; zero basis traffic).
    basis: SharedSeedBasis,
    /// Round counter — part of the shared basis derivation, so the basis
    /// walks every round without any coordination traffic. Re-aligned to
    /// the trainer step on checkpoint restore ([`Collective::set_round`]).
    round: u64,
    /// Per-worker, per-region error-feedback residuals (empty 0×0 mats
    /// for 1-D regions; lazily sized on the first round). Deliberately
    /// NOT checkpointed — like optimizer subspace state, they are
    /// transient deferred energy; a restore drops at most one round's
    /// untransmitted bulk.
    residuals: Vec<Vec<Mat>>,
    /// Reusable scratch (per-worker wire buffers + pack/reconstruct
    /// intermediates): steady-state rounds do no heap allocation here —
    /// only the shared-basis regeneration (QR of a fresh gaussian, the
    /// scheme's defining cost) allocates.
    packed: Vec<Vec<f32>>,
    g: Mat,
    factor: Mat,
    recon: Mat,
}

impl LowRankAllReduce {
    pub fn new(
        transport: Box<dyn Transport>,
        rank: usize,
        seed: u64,
    ) -> LowRankAllReduce {
        assert!(rank >= 1);
        LowRankAllReduce {
            transport,
            rank,
            basis: SharedSeedBasis { seed },
            round: 0,
            residuals: Vec::new(),
            packed: Vec::new(),
            g: Mat::default(),
            factor: Mat::default(),
            recon: Mat::default(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Rounds completed so far (= the round index the *next* call will
    /// derive its bases from is `rounds_done()`).
    pub fn rounds_done(&self) -> u64 {
        self.round
    }

    /// Test/diagnostic access to a worker's residual accumulator.
    pub fn residual(&self, worker: usize, region: usize) -> Option<&Mat> {
        self.residuals.get(worker)?.get(region)
    }

    /// The shared basis for `region` at round `round` of this collective
    /// (what every worker regenerates locally) — delegated to the
    /// subspace subsystem's shared-seed provider. Exposed so tests and
    /// the analysis tooling can reproduce the exact wire view.
    pub fn basis_for(&self, round: u64, region: usize, long: usize) -> Mat {
        self.basis.at(round, region as u64, long, self.rank)
    }
}

impl Collective for LowRankAllReduce {
    fn label(&self) -> &'static str {
        "lowrank"
    }

    fn transport(&self) -> &dyn Transport {
        &*self.transport
    }

    fn set_round(&mut self, round: u64) {
        self.round = round;
        // A restore abandons the current trajectory: stale deferred
        // energy from it must not leak into the resumed run's gradients.
        // Residuals re-initialize to zero on the next round.
        self.residuals.clear();
    }

    fn all_reduce_mean(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
    ) -> Result<CommStats> {
        let n = self.transport.world_size();
        let local = self.transport.local_endpoints();
        if workers.len() != local {
            bail!(
                "lowrank collective: {} buffers for {local} local \
                 endpoints (world {n})",
                workers.len()
            );
        }
        if workers.iter().any(|w| w.len() != layout.total_floats) {
            bail!(
                "lowrank collective: buffer length != layout total {}",
                layout.total_floats
            );
        }
        let packed_len = layout.packed_floats(self.rank);
        let dense = layout.total_floats;
        let compression = dense as f64 / packed_len.max(1) as f64;
        if n == 1 {
            // Nothing crosses a wire with one worker: pass the gradient
            // through untouched (no deferral via error feedback either),
            // keeping --comm lowrank ≡ dense at world size 1.
            return Ok(CommStats {
                bytes_per_worker: 0,
                payload_floats: packed_len,
                dense_floats: dense,
                compression,
                residual_norm: 0.0,
                hops: 0,
            });
        }

        // One-time buffer growth below (residual accumulators, packed
        // wire buffers) lands in the CommBuffers memory domain; the
        // steady-state round allocates nothing, so the scope guard is
        // the only per-round cost (two TLS writes).
        let _mem = crate::util::alloc::scope(
            crate::util::alloc::MemDomain::CommBuffers,
        );
        if self.residuals.is_empty() {
            self.residuals = (0..local)
                .map(|_| {
                    layout
                        .regions
                        .iter()
                        .map(|reg| {
                            if reg.is_matrix() {
                                Mat::zeros(reg.rows, reg.cols)
                            } else {
                                Mat::default()
                            }
                        })
                        .collect()
                })
                .collect();
        }

        // Shared bases for this round — identical on every worker by
        // construction, so they never touch the transport.
        let round = self.round;
        let bases: Vec<Mat> = layout
            .regions
            .iter()
            .enumerate()
            .map(|(k, reg)| {
                if reg.is_matrix() {
                    let (long, _) = reg.oriented();
                    self.basis_for(round, k, long)
                } else {
                    Mat::default()
                }
            })
            .collect();

        // Split field borrows: scratch, residuals and the transport are
        // used side by side below.
        let rank = self.rank;
        let Self { transport, residuals, packed, g, factor, recon, .. } =
            self;

        // ---- pack: per worker, factors for matrices + raw 1-D tails ----
        // All intermediates live in the owned scratch; steady-state
        // rounds allocate nothing on this path.
        if packed.len() != local {
            *packed =
                (0..local).map(|_| Vec::with_capacity(packed_len)).collect();
        }
        for (w, buf) in workers.iter().enumerate() {
            let p = &mut packed[w];
            p.clear();
            for (k, reg) in layout.regions.iter().enumerate() {
                let slice = &buf[reg.offset..reg.offset + reg.len];
                if reg.is_matrix() {
                    g.resize_to(reg.rows, reg.cols);
                    g.data.copy_from_slice(slice);
                    g.axpy(1.0, &residuals[w][k]); // G' = G + E
                    let basis = &bases[k];
                    if reg.rows >= reg.cols {
                        matmul_tn_into(basis, g, factor); // r × cols
                        matmul_into(basis, factor, recon);
                    } else {
                        matmul_into(g, basis, factor); // rows × r
                        matmul_nt_into(factor, basis, recon);
                    }
                    // Error feedback in place: E ← G' − transmitted.
                    residuals[w][k].assign_zip(g, recon, |a, b| a - b);
                    p.extend_from_slice(&factor.data);
                } else {
                    p.extend_from_slice(slice);
                }
            }
            debug_assert_eq!(p.len(), packed_len);
        }

        // ---- the only traffic: ring all-reduce over the packed factors --
        let tstats = transport.all_reduce_sum(packed)?;

        // ---- mean + local reconstruction (identical on every worker) ---
        let inv = 1.0 / n as f32;
        let mean = &mut packed[0];
        for x in mean.iter_mut() {
            *x *= inv;
        }
        let (first, rest) = workers.split_first_mut().unwrap();
        let mut poff = 0usize;
        for (k, reg) in layout.regions.iter().enumerate() {
            let fl = reg.factor_floats(rank);
            let src = &mean[poff..poff + fl];
            let dst = &mut first[reg.offset..reg.offset + reg.len];
            if reg.is_matrix() {
                let basis = &bases[k];
                if reg.rows >= reg.cols {
                    factor.resize_to(basis.cols, reg.cols);
                    factor.data.copy_from_slice(src);
                    matmul_into(basis, factor, recon);
                } else {
                    factor.resize_to(reg.rows, basis.cols);
                    factor.data.copy_from_slice(src);
                    matmul_nt_into(factor, basis, recon);
                }
                dst.copy_from_slice(&recon.data);
            } else {
                dst.copy_from_slice(src);
            }
            poff += fl;
        }
        for w in rest.iter_mut() {
            w.copy_from_slice(first);
        }

        // Mean over the residual accumulators living in THIS process:
        // all n workers for the in-process transport, just our own rank's
        // for a socket backend (residuals are per-worker local state that
        // never crosses the wire).
        let residual_norm = residuals
            .iter()
            .map(|per_region| {
                per_region
                    .iter()
                    .map(|e| e.fro_norm_sq())
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / local as f64;

        self.round += 1;
        Ok(CommStats {
            bytes_per_worker: tstats.bytes_sent_per_worker,
            payload_floats: packed_len,
            dense_floats: dense,
            compression,
            residual_norm,
            hops: tstats.hops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::RingTransport;
    use crate::util::rng::Rng;

    fn layout() -> GradLayout {
        // Tall matrix, wide matrix, and a 1-D tail.
        GradLayout::from_shapes(&[vec![10, 6], vec![5, 12], vec![7]])
    }

    fn rand_workers(n: usize, total: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; total];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect()
    }

    #[test]
    fn all_workers_get_identical_reconstruction() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(3)),
            4,
            11,
        );
        let mut bufs = rand_workers(3, layout.total_floats, 1);
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(bufs[0], bufs[1]);
        assert_eq!(bufs[0], bufs[2]);
    }

    #[test]
    fn dense_tail_is_exact_mean() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            5,
        );
        let mut bufs = rand_workers(2, layout.total_floats, 2);
        let tail = layout.regions[2];
        let expect: Vec<f32> = (0..tail.len)
            .map(|i| {
                (bufs[0][tail.offset + i] + bufs[1][tail.offset + i]) / 2.0
            })
            .collect();
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        for (i, &want) in expect.iter().enumerate() {
            let got = bufs[0][tail.offset + i];
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn single_worker_is_passthrough() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(1)),
            4,
            5,
        );
        let mut bufs = rand_workers(1, layout.total_floats, 3);
        let before = bufs[0].clone();
        let stats = c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(bufs[0], before);
        assert_eq!(stats.bytes_per_worker, 0);
        assert!(stats.compression > 1.0);
    }

    #[test]
    fn set_round_realigns_basis_schedule() {
        // A fresh collective fast-forwarded with set_round(3) must be
        // bitwise-equivalent to one that already ran 3 rounds with zero
        // gradients (zero input leaves residuals at zero, isolating the
        // schedule) — the checkpoint-restore contract.
        let layout = layout();
        let mk = || {
            LowRankAllReduce::new(Box::new(RingTransport::new(2)), 4, 5)
        };
        let mut advanced = mk();
        for _ in 0..3 {
            let mut z: Vec<Vec<f32>> =
                (0..2).map(|_| vec![0.0f32; layout.total_floats]).collect();
            advanced.all_reduce_mean(&mut z, &layout).unwrap();
        }
        let mut restored = mk();
        restored.set_round(3);
        assert_eq!(restored.rounds_done(), 3);
        let bufs = rand_workers(2, layout.total_floats, 17);
        let mut x = bufs.clone();
        let mut y = bufs.clone();
        advanced.all_reduce_mean(&mut x, &layout).unwrap();
        restored.all_reduce_mean(&mut y, &layout).unwrap();
        assert_eq!(x[0], y[0], "restored schedule must match continuous");
        // Without realignment the basis (hence the output) differs.
        let mut fresh = mk();
        let mut w = bufs;
        fresh.all_reduce_mean(&mut w, &layout).unwrap();
        assert_ne!(x[0], w[0]);
    }

    #[test]
    fn set_round_clears_stale_residuals() {
        // Restoring into an already-run collective must not leak the
        // abandoned trajectory's deferred energy into the resumed run.
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            5,
        );
        let mut bufs = rand_workers(2, layout.total_floats, 9);
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert!(
            c.residual(0, 0).map(|e| e.fro_norm() > 0.0).unwrap_or(false),
            "round with real gradients must leave a residual"
        );
        c.set_round(0);
        assert!(
            c.residual(0, 0).is_none(),
            "restore must drop stale deferred energy"
        );
        // And the collective keeps working after the reset.
        let mut bufs = rand_workers(2, layout.total_floats, 10);
        c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(bufs[0], bufs[1]);
    }

    #[test]
    fn steady_state_rounds_reuse_scratch() {
        // Many rounds on one collective must keep working with the
        // reusable scratch (shape cycling across regions included).
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            8,
        );
        for seed in 0..10 {
            let mut bufs = rand_workers(2, layout.total_floats, 200 + seed);
            let stats = c.all_reduce_mean(&mut bufs, &layout).unwrap();
            assert_eq!(stats.payload_floats, layout.packed_floats(4));
            assert_eq!(bufs[0], bufs[1]);
        }
        assert_eq!(c.rounds_done(), 10);
    }

    #[test]
    fn rejects_bad_inputs() {
        let layout = layout();
        let mut c = LowRankAllReduce::new(
            Box::new(RingTransport::new(2)),
            4,
            5,
        );
        let mut wrong_world = rand_workers(1, layout.total_floats, 4);
        assert!(c.all_reduce_mean(&mut wrong_world, &layout).is_err());
        let mut wrong_len = vec![vec![0.0f32; 3], vec![0.0f32; 3]];
        assert!(c.all_reduce_mean(&mut wrong_len, &layout).is_err());
    }
}
