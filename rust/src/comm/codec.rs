//! `--wire f32|bf16|int8`: the payload codec for the low-rank
//! collective's factor exchange.
//!
//! The low-rank collective already ships only rank-r factors (~7.9×
//! fewer floats than dense on the proxy layout); this module shrinks
//! the *bytes per float*:
//!
//! * **f32** — identity; the packed factors travel as exact
//!   little-endian f32, bitwise-identical to every prior release.
//! * **bf16** — round-to-nearest-even truncation to the top 16 bits of
//!   each f32 (sign + 8-bit exponent + 7-bit mantissa): 2 bytes/float,
//!   relative error ≤ 2⁻⁸ per element.
//! * **int8** — per-column affine quantization of each factor block: a
//!   f32 `maxabs/127` scale per column, then one signed byte per
//!   element (row-major): ~1 byte/float + 4 bytes/column of scales,
//!   absolute error ≤ scale/2 per element.
//!
//! 1-D regions (biases, norms) are never compressed by the low-rank
//! collective and keep exact f32 bytes under every codec — only matrix
//! factor blocks quantize. Quantization error is NOT lost: the
//! collective folds it into the same per-worker error-feedback
//! residuals that absorb the low-rank projection error (each worker
//! subtracts its own *dequantized* reconstruction), so the energy is
//! reinjected over subsequent rounds — the compression/EF composition
//! analyzed by the Lotus line of work in PAPERS.md.
//!
//! Determinism: encode and decode are pure element-wise f32 arithmetic
//! in a fixed order, so every rank producing or consuming a block
//! computes bit-identical bytes and floats — quantized runs stay
//! bitwise-reproducible across transports (inproc ≡ TCP), just not
//! bitwise-equal to `--wire f32` runs.

use super::collective::GradRegion;
use super::net::wire::NetError;

/// Payload encoding for the low-rank factor exchange (`--wire …`).
/// The discriminant is the wire tag byte carried by quantized `Gather`
/// frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum WireCodec {
    /// Exact f32 little-endian bytes (the default).
    F32 = 0,
    /// Round-to-nearest-even bf16 truncation.
    Bf16 = 1,
    /// Per-column-scaled signed bytes.
    Int8 = 2,
}

impl WireCodec {
    pub fn label(self) -> &'static str {
        match self {
            WireCodec::F32 => "f32",
            WireCodec::Bf16 => "bf16",
            WireCodec::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<WireCodec> {
        match s.to_ascii_lowercase().as_str() {
            "f32" => Some(WireCodec::F32),
            "bf16" | "bfloat16" => Some(WireCodec::Bf16),
            "int8" | "i8" => Some(WireCodec::Int8),
            _ => None,
        }
    }

    /// The frame tag byte for this codec.
    pub fn tag(self) -> u8 {
        self as u8
    }

    /// Inverse of [`WireCodec::tag`]; `None` is the
    /// [`NetError::UnknownWireCodec`] path at the receiver.
    pub fn from_tag(t: u8) -> Option<WireCodec> {
        match t {
            0 => Some(WireCodec::F32),
            1 => Some(WireCodec::Bf16),
            2 => Some(WireCodec::Int8),
            _ => None,
        }
    }
}

/// bf16 with round-to-nearest-even, NaN forced quiet.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return 0x7FC0;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits.wrapping_add(round)) >> 16) as u16
}

#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// The factor matrix a region contributes to the packed vector:
/// `(floats, columns)` at `rank`. Tall regions exchange an r×short
/// factor (short columns); wide regions exchange a short×r factor
/// (r columns); 1-D regions travel raw (single column, never
/// quantized). Pure layout arithmetic — every rank derives the same
/// geometry locally.
pub fn factor_geometry(r: &GradRegion, rank: usize) -> (usize, usize) {
    if r.is_matrix() {
        let (long, short) = r.oriented();
        let rr = rank.min(long);
        let cols = if r.rows >= r.cols { short } else { rr };
        (rr * short, cols)
    } else {
        (r.len, 1)
    }
}

/// Exact encoded byte count for `regions` at `rank` under `codec`.
pub fn encoded_len(
    codec: WireCodec,
    regions: &[GradRegion],
    rank: usize,
) -> usize {
    regions
        .iter()
        .map(|r| {
            let (floats, cols) = factor_geometry(r, rank);
            if !r.is_matrix() {
                return 4 * floats;
            }
            match codec {
                WireCodec::F32 => 4 * floats,
                WireCodec::Bf16 => 2 * floats,
                WireCodec::Int8 => 4 * cols + floats,
            }
        })
        .sum()
}

/// Encode the packed factor vector `src` (region blocks concatenated in
/// layout order, `layout.packed_floats(rank)` long for the regions
/// given) into `out` (cleared and reused — steady-state rounds reuse
/// its capacity).
// hot-path
pub fn encode_packed(
    codec: WireCodec,
    regions: &[GradRegion],
    rank: usize,
    src: &[f32],
    out: &mut Vec<u8>,
) {
    out.clear();
    out.reserve(encoded_len(codec, regions, rank));
    let mut off = 0usize;
    for r in regions {
        let (floats, cols) = factor_geometry(r, rank);
        let block = &src[off..off + floats];
        off += floats;
        if !r.is_matrix() || codec == WireCodec::F32 {
            for &x in block {
                out.extend_from_slice(&x.to_le_bytes());
            }
            continue;
        }
        match codec {
            WireCodec::Bf16 => {
                for &x in block {
                    out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
                }
            }
            WireCodec::Int8 => {
                let rows = floats / cols.max(1);
                for c in 0..cols {
                    let mut maxabs = 0.0f32;
                    for row in 0..rows {
                        maxabs = maxabs.max(block[row * cols + c].abs());
                    }
                    let scale = maxabs / 127.0;
                    out.extend_from_slice(&scale.to_le_bytes());
                }
                let scales_at = out.len() - 4 * cols;
                for row in 0..rows {
                    for c in 0..cols {
                        let sb = &out[scales_at + 4 * c..scales_at + 4 * c + 4];
                        let scale = f32::from_le_bytes([
                            sb[0], sb[1], sb[2], sb[3],
                        ]);
                        let q = if scale > 0.0 {
                            (block[row * cols + c] / scale)
                                .round()
                                .clamp(-127.0, 127.0)
                                as i8
                        } else {
                            0
                        };
                        out.push(q as u8);
                    }
                }
            }
            WireCodec::F32 => unreachable!("handled above"),
        }
    }
    debug_assert_eq!(off, src.len());
}

/// Decode a packed byte block back into floats (the packed-vector
/// layout `encode_packed` produced). `dst` is resized to the packed
/// float count. A byte count that disagrees with the layout + codec is
/// the typed [`NetError::QuantizedPayloadMismatch`] — never a panic,
/// whatever a peer sends.
// hot-path
pub fn decode_packed(
    codec: WireCodec,
    regions: &[GradRegion],
    rank: usize,
    bytes: &[u8],
    dst: &mut Vec<f32>,
) -> Result<(), NetError> {
    let expected = encoded_len(codec, regions, rank);
    if bytes.len() != expected {
        return Err(NetError::QuantizedPayloadMismatch {
            expected,
            got: bytes.len(),
        });
    }
    let total: usize = regions
        .iter()
        .map(|r| factor_geometry(r, rank).0)
        .sum();
    dst.clear();
    dst.reserve(total);
    let mut at = 0usize;
    for r in regions {
        let (floats, cols) = factor_geometry(r, rank);
        if !r.is_matrix() || codec == WireCodec::F32 {
            for _ in 0..floats {
                let b = &bytes[at..at + 4];
                dst.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
                at += 4;
            }
            continue;
        }
        match codec {
            WireCodec::Bf16 => {
                for _ in 0..floats {
                    let b = &bytes[at..at + 2];
                    dst.push(bf16_to_f32(u16::from_le_bytes([b[0], b[1]])));
                    at += 2;
                }
            }
            WireCodec::Int8 => {
                let rows = floats / cols.max(1);
                let scales_at = at;
                at += 4 * cols;
                for _row in 0..rows {
                    for c in 0..cols {
                        let sb = &bytes[scales_at + 4 * c..scales_at + 4 * c + 4];
                        let scale = f32::from_le_bytes([
                            sb[0], sb[1], sb[2], sb[3],
                        ]);
                        let q = bytes[at] as i8;
                        at += 1;
                        dst.push(q as f32 * scale);
                    }
                }
            }
            WireCodec::F32 => unreachable!("handled above"),
        }
    }
    debug_assert_eq!(at, bytes.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn matrix_region(rows: usize, cols: usize) -> GradRegion {
        GradRegion { offset: 0, len: rows * cols, rows, cols }
    }

    fn vec_region(len: usize) -> GradRegion {
        GradRegion { offset: 0, len, rows: len, cols: 1 }
    }

    #[test]
    fn parse_label_tag_roundtrip() {
        for c in [WireCodec::F32, WireCodec::Bf16, WireCodec::Int8] {
            assert_eq!(WireCodec::parse(c.label()), Some(c));
            assert_eq!(WireCodec::from_tag(c.tag()), Some(c));
        }
        assert_eq!(WireCodec::parse("fp8"), None);
        assert_eq!(WireCodec::from_tag(3), None);
        assert_eq!(WireCodec::from_tag(255), None);
    }

    #[test]
    fn bf16_conversion_bounds_and_exactness() {
        // Values with ≤7 mantissa bits are exact.
        for x in [0.0f32, 1.0, -2.5, 0.15625, 1024.0, -0.0078125] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)), x, "{x}");
        }
        // General values: relative error ≤ 2^-8.
        let mut rng = Rng::new(11);
        let mut v = vec![0.0f32; 4096];
        rng.fill_normal(&mut v, 1.0);
        for &x in &v {
            let y = bf16_to_f32(f32_to_bf16(x));
            assert!(
                (y - x).abs() <= x.abs() / 256.0 + f32::EPSILON,
                "{x} -> {y}"
            );
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn f32_codec_is_the_identity() {
        let regions = [matrix_region(8, 4), vec_region(5)];
        let rank = 3;
        let floats: usize =
            regions.iter().map(|r| factor_geometry(r, rank).0).sum();
        let mut rng = Rng::new(2);
        let mut src = vec![0.0f32; floats];
        rng.fill_normal(&mut src, 1.0);
        let mut bytes = Vec::new();
        encode_packed(WireCodec::F32, &regions, rank, &src, &mut bytes);
        assert_eq!(bytes.len(), 4 * floats);
        let mut back = Vec::new();
        decode_packed(WireCodec::F32, &regions, rank, &bytes, &mut back)
            .unwrap();
        assert_eq!(back, src, "f32 must be bitwise identity");
    }

    #[test]
    fn bf16_packed_roundtrip_respects_error_bound() {
        let regions = [matrix_region(16, 6), vec_region(9), matrix_region(4, 20)];
        let rank = 5;
        let floats: usize =
            regions.iter().map(|r| factor_geometry(r, rank).0).sum();
        let mut rng = Rng::new(7);
        let mut src = vec![0.0f32; floats];
        rng.fill_normal(&mut src, 1.0);
        let mut bytes = Vec::new();
        encode_packed(WireCodec::Bf16, &regions, rank, &src, &mut bytes);
        assert_eq!(bytes.len(), encoded_len(WireCodec::Bf16, &regions, rank));
        let mut back = Vec::new();
        decode_packed(WireCodec::Bf16, &regions, rank, &bytes, &mut back)
            .unwrap();
        // 1-D tail region (index 1 in packed order) is exact f32.
        let m0 = factor_geometry(&regions[0], rank).0;
        let v1 = regions[1].len;
        assert_eq!(&back[m0..m0 + v1], &src[m0..m0 + v1]);
        for (&x, &y) in src.iter().zip(&back) {
            assert!((y - x).abs() <= x.abs() / 256.0 + f32::EPSILON);
        }
    }

    #[test]
    fn int8_packed_roundtrip_respects_per_column_bound() {
        let regions = [matrix_region(32, 8), matrix_region(3, 24)];
        let rank = 6;
        let floats: usize =
            regions.iter().map(|r| factor_geometry(r, rank).0).sum();
        let mut rng = Rng::new(13);
        let mut src = vec![0.0f32; floats];
        rng.fill_normal(&mut src, 1.0);
        // Make column magnitudes wildly uneven so a global scale would
        // fail the bound and only per-column scales pass.
        for (i, x) in src.iter_mut().enumerate() {
            if i % 3 == 0 {
                *x *= 100.0;
            }
        }
        let mut bytes = Vec::new();
        encode_packed(WireCodec::Int8, &regions, rank, &src, &mut bytes);
        assert_eq!(bytes.len(), encoded_len(WireCodec::Int8, &regions, rank));
        let mut back = Vec::new();
        decode_packed(WireCodec::Int8, &regions, rank, &bytes, &mut back)
            .unwrap();
        let mut off = 0usize;
        for r in &regions {
            let (floats, cols) = factor_geometry(r, rank);
            let rows = floats / cols;
            for c in 0..cols {
                let mut maxabs = 0.0f32;
                for row in 0..rows {
                    maxabs = maxabs.max(src[off + row * cols + c].abs());
                }
                let half_step = maxabs / 127.0 / 2.0 + 1e-6;
                for row in 0..rows {
                    let x = src[off + row * cols + c];
                    let y = back[off + row * cols + c];
                    assert!(
                        (y - x).abs() <= half_step * 1.001,
                        "col {c}: {x} -> {y}, bound {half_step}"
                    );
                }
            }
            off += floats;
        }
    }

    #[test]
    fn int8_all_zero_column_stays_exact() {
        let regions = [matrix_region(8, 2)];
        let rank = 2;
        let src = vec![0.0f32; factor_geometry(&regions[0], rank).0];
        let mut bytes = Vec::new();
        encode_packed(WireCodec::Int8, &regions, rank, &src, &mut bytes);
        let mut back = Vec::new();
        decode_packed(WireCodec::Int8, &regions, rank, &bytes, &mut back)
            .unwrap();
        assert!(back.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn decode_rejects_wrong_byte_count_by_name() {
        let regions = [matrix_region(8, 4)];
        let rank = 2;
        let src = vec![1.0f32; factor_geometry(&regions[0], rank).0];
        let mut bytes = Vec::new();
        encode_packed(WireCodec::Bf16, &regions, rank, &src, &mut bytes);
        bytes.pop();
        let mut back = Vec::new();
        let err =
            decode_packed(WireCodec::Bf16, &regions, rank, &bytes, &mut back)
                .unwrap_err();
        assert_eq!(err.name(), "quantized-payload-mismatch");
        // Scale truncation on int8 blocks is the same named failure.
        let mut ibytes = Vec::new();
        encode_packed(WireCodec::Int8, &regions, rank, &src, &mut ibytes);
        ibytes.truncate(3);
        let err =
            decode_packed(WireCodec::Int8, &regions, rank, &ibytes, &mut back)
                .unwrap_err();
        assert_eq!(err.name(), "quantized-payload-mismatch");
    }

    #[test]
    fn encode_is_deterministic() {
        let regions = [matrix_region(16, 6), vec_region(4)];
        let rank = 4;
        let floats: usize =
            regions.iter().map(|r| factor_geometry(r, rank).0).sum();
        let mut rng = Rng::new(21);
        let mut src = vec![0.0f32; floats];
        rng.fill_normal(&mut src, 1.0);
        for codec in [WireCodec::Bf16, WireCodec::Int8] {
            let mut a = Vec::new();
            let mut b = Vec::new();
            encode_packed(codec, &regions, rank, &src, &mut a);
            encode_packed(codec, &regions, rank, &src, &mut b);
            assert_eq!(a, b);
        }
    }
}
