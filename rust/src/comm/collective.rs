//! The collective layer of the `comm` subsystem: *what* is exchanged per
//! gradient round, on top of a [`Transport`] that decides *how*.
//!
//! [`Collective::all_reduce_mean`] is the trainer-facing contract: given
//! every worker's flat gradient vector and the parameter layout, leave
//! the (possibly compressed) mean gradient in every buffer and report
//! [`CommStats`]. [`DenseAllReduce`] exchanges the full vectors —
//! bitwise-equivalent to the legacy `coordinator::allreduce::Ring` path.
//! The subspace-compressed variant lives in [`super::lowrank`].

use anyhow::{bail, Result};

use super::transport::Transport;

/// One parameter's slice of the flat gradient vector.
#[derive(Clone, Copy, Debug)]
pub struct GradRegion {
    /// Start offset into the flat vector.
    pub offset: usize,
    /// Element count (rows × cols).
    pub len: usize,
    /// Matrix geometry; 1-D parameters are (len, 1).
    pub rows: usize,
    pub cols: usize,
}

impl GradRegion {
    /// Whether this region is a genuine matrix (compressible): both
    /// dimensions non-trivial.
    pub fn is_matrix(&self) -> bool {
        self.rows > 1 && self.cols > 1
    }

    /// (long, short) dimensions — the shared-seed basis lives on the
    /// long side, the exchanged factor is r × short.
    pub fn oriented(&self) -> (usize, usize) {
        if self.rows >= self.cols {
            (self.rows, self.cols)
        } else {
            (self.cols, self.rows)
        }
    }

    /// Floats the low-rank collective exchanges for this region at the
    /// given rank: r·short for matrices (capped at the exact size), the
    /// raw length for 1-D parameters (never compressed).
    pub fn factor_floats(&self, rank: usize) -> usize {
        if self.is_matrix() {
            let (long, short) = self.oriented();
            rank.min(long) * short
        } else {
            self.len
        }
    }
}

/// The flat-gradient layout: one region per parameter, in ABI order.
#[derive(Clone, Debug)]
pub struct GradLayout {
    pub regions: Vec<GradRegion>,
    pub total_floats: usize,
}

impl GradLayout {
    /// Build from parameter shapes (ABI order). Shapes with other than
    /// two dimensions are treated as flat 1-D regions.
    pub fn from_shapes(shapes: &[Vec<usize>]) -> GradLayout {
        // Layout metadata is comm-owned memory (ISSUE 9 attribution).
        let _mem = crate::util::alloc::scope(
            crate::util::alloc::MemDomain::CommBuffers,
        );
        let mut regions = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for sh in shapes {
            let len: usize = sh.iter().product();
            let (rows, cols) =
                if sh.len() == 2 { (sh[0], sh[1]) } else { (len, 1) };
            regions.push(GradRegion { offset: off, len, rows, cols });
            off += len;
        }
        GradLayout { regions, total_floats: off }
    }

    /// Floats per worker the low-rank collective puts on the wire.
    pub fn packed_floats(&self, rank: usize) -> usize {
        self.regions.iter().map(|r| r.factor_floats(rank)).sum()
    }

    /// Deterministic 64-bit fingerprint of the layout geometry (FNV-1a
    /// over every region's offset/len/rows/cols plus the total). The
    /// `comm::net` handshake exchanges it so two processes whose models
    /// disagree — different config, different parameter order — are
    /// rejected by name before the first gradient round instead of
    /// silently reducing mismatched bytes.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mix = |h: u64, x: u64| (h ^ x).wrapping_mul(PRIME);
        h = mix(h, self.total_floats as u64);
        h = mix(h, self.regions.len() as u64);
        for r in &self.regions {
            h = mix(h, r.offset as u64);
            h = mix(h, r.len as u64);
            h = mix(h, r.rows as u64);
            h = mix(h, r.cols as u64);
        }
        h
    }
}

/// Per-round collective accounting, recorded into the metrics stream.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Bytes sent by the busiest worker this round.
    pub bytes_per_worker: usize,
    /// Floats exchanged per worker buffer (the wire payload length).
    pub payload_floats: usize,
    /// Floats a dense exchange would have carried (layout total).
    pub dense_floats: usize,
    /// dense_floats / payload_floats.
    pub compression: f64,
    /// Mean-over-workers Frobenius norm of the error-feedback residual
    /// accumulators after this round (0 for dense).
    pub residual_norm: f64,
    /// Transport hops per worker.
    pub hops: usize,
    /// Total wall time buckets spent in flight on the transport this
    /// round (begin → finish-return, summed over buckets; 0 when the
    /// single-shot path ran).
    pub overlap_flight_ns: u64,
    /// Of that flight time, how much the coordinator actually waited
    /// inside `reduce_finish`. `1 − wait/flight` is the overlap ratio:
    /// the fraction of wire time hidden behind coordinator compute.
    pub overlap_wait_ns: u64,
}

/// A gradient collective: reduces per-worker flat gradients to their
/// mean, in place (every buffer equal on return).
///
/// `workers` holds one buffer per LOCAL endpoint of the underlying
/// transport — all N of them for the in-process ring, exactly one for a
/// TCP rank — while the mean is always over the global world size.
pub trait Collective: Send {
    fn label(&self) -> &'static str;

    /// The transport this collective reduces over — the trainer uses it
    /// for world topology (`world_size`/`local_endpoints`) and the loss
    /// sidecar gather, so those stay in lockstep with the gradient path.
    fn transport(&self) -> &dyn Transport;

    fn all_reduce_mean(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
    ) -> Result<CommStats>;

    /// Bucketed variant: reduce the layout bucket-by-bucket per `plan`,
    /// optionally keeping up to two buckets in flight (`overlap`) so
    /// wire time hides behind the coordinator's pack/unpack work.
    ///
    /// Contract: for a FIXED plan the result is bitwise identical
    /// whether `overlap` is on or off — overlap changes only when
    /// wall-clock work happens, never the fold order (pinned in
    /// rust/tests/comm_props.rs / net_props.rs). The default falls back
    /// to the single-shot path (correct, just unpipelined) for
    /// collectives that don't implement bucketing.
    fn all_reduce_mean_bucketed(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
        _plan: &super::bucket::BucketPlan,
        _overlap: bool,
    ) -> Result<CommStats> {
        self.all_reduce_mean(workers, layout)
    }

    /// Re-align any round-dependent schedule (the low-rank collective's
    /// shared-basis derivation) with a restored trainer step, so a
    /// resumed run regenerates the same basis sequence a continuous run
    /// would — and drop trajectory-dependent state (error-feedback
    /// residuals) accumulated on the abandoned trajectory. Default no-op
    /// for stateless collectives.
    fn set_round(&mut self, _round: u64) {}
}

/// Full-gradient exchange: the layout is ignored beyond a length check;
/// results are bitwise-identical to the legacy single-shot ring (pinned
/// in rust/tests/comm_props.rs).
pub struct DenseAllReduce {
    transport: Box<dyn Transport>,
    /// Reusable staging shells for the bucketed pipeline (one
    /// `Vec<Vec<f32>>` per in-flight bucket, ping-ponged through
    /// `reduce_begin`/`reduce_finish` so steady-state rounds allocate
    /// nothing).
    slots: std::collections::VecDeque<Vec<Vec<f32>>>,
    /// Begin timestamps of in-flight buckets (FIFO, capacity 2).
    inflight_since: std::collections::VecDeque<std::time::Instant>,
}

impl DenseAllReduce {
    pub fn new(transport: Box<dyn Transport>) -> DenseAllReduce {
        DenseAllReduce {
            transport,
            slots: std::collections::VecDeque::with_capacity(2),
            inflight_since: std::collections::VecDeque::with_capacity(2),
        }
    }

    fn validate(
        &self,
        workers: &[Vec<f32>],
        layout: &GradLayout,
    ) -> Result<()> {
        let n = self.transport.world_size();
        let local = self.transport.local_endpoints();
        if workers.len() != local {
            bail!(
                "dense collective: {} buffers for {local} local endpoints \
                 (world {n})",
                workers.len()
            );
        }
        if workers.iter().any(|w| w.len() != layout.total_floats) {
            bail!(
                "dense collective: buffer length != layout total {}",
                layout.total_floats
            );
        }
        Ok(())
    }

    /// Stage bucket `b`'s span of every worker into a pooled shell and
    /// hand it to the transport.
    // hot-path
    fn bucket_begin(
        &mut self,
        workers: &[Vec<f32>],
        plan: &super::bucket::BucketPlan,
        b: usize,
        max_floats: usize,
    ) -> Result<()> {
        let bk = plan.buckets()[b];
        let mut shell = self.slots.pop_front().unwrap_or_default();
        while shell.len() < workers.len() {
            shell.push(Vec::with_capacity(max_floats));
        }
        shell.truncate(workers.len());
        for (dst, src) in shell.iter_mut().zip(workers.iter()) {
            dst.clear();
            dst.extend_from_slice(&src[bk.offset..bk.offset + bk.len]);
        }
        self.inflight_since.push_back(std::time::Instant::now());
        self.transport.reduce_begin(shell, b as u8)
    }

    /// Wait for the oldest in-flight bucket, copy it back into the
    /// workers, and recycle the shell. Returns (wire stats, flight ns,
    /// wait ns) for the bucket.
    // hot-path
    fn bucket_finish(
        &mut self,
        workers: &mut [Vec<f32>],
        plan: &super::bucket::BucketPlan,
        b: usize,
    ) -> Result<(crate::comm::transport::TransportStats, u64, u64)> {
        let bk = plan.buckets()[b];
        let waited = std::time::Instant::now();
        let (shell, tstats) = self.transport.reduce_finish()?;
        let wait_ns = waited.elapsed().as_nanos() as u64;
        let flight_ns = match self.inflight_since.pop_front() {
            Some(t0) => t0.elapsed().as_nanos() as u64,
            None => wait_ns,
        };
        for (src, dst) in shell.iter().zip(workers.iter_mut()) {
            dst[bk.offset..bk.offset + bk.len].copy_from_slice(src);
        }
        self.slots.push_back(shell);
        Ok((tstats, flight_ns, wait_ns))
    }
}

impl Collective for DenseAllReduce {
    fn label(&self) -> &'static str {
        "dense"
    }

    fn transport(&self) -> &dyn Transport {
        &*self.transport
    }

    fn all_reduce_mean(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
    ) -> Result<CommStats> {
        let n = self.transport.world_size();
        self.validate(workers, layout)?;
        let tstats = self.transport.all_reduce_sum(workers)?;
        // Mean, applied exactly like the legacy Ring::all_reduce_mean.
        let inv = 1.0 / n as f32;
        for b in workers.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
        Ok(CommStats {
            bytes_per_worker: tstats.bytes_sent_per_worker,
            payload_floats: layout.total_floats,
            dense_floats: layout.total_floats,
            compression: 1.0,
            residual_norm: 0.0,
            hops: tstats.hops,
            overlap_flight_ns: 0,
            overlap_wait_ns: 0,
        })
    }

    /// Depth-2 bucket pipeline over the dense vector. Bucket spans and
    /// ring fold order are fixed by the plan, so overlap-on and
    /// overlap-off produce bitwise-identical results; the mean is
    /// applied once after every bucket lands, exactly where the
    /// single-shot path applies it.
    // hot-path
    fn all_reduce_mean_bucketed(
        &mut self,
        workers: &mut [Vec<f32>],
        layout: &GradLayout,
        plan: &super::bucket::BucketPlan,
        overlap: bool,
    ) -> Result<CommStats> {
        if plan.len() <= 1 {
            return self.all_reduce_mean(workers, layout);
        }
        let n = self.transport.world_size();
        self.validate(workers, layout)?;
        let nb = plan.len();
        let maxf = plan.max_dense_floats();
        let overlap = overlap && self.transport.supports_overlap();
        let mut bytes = 0usize;
        let mut hops = 0usize;
        let mut flight_ns = 0u64;
        let mut wait_ns = 0u64;
        // The overlap clock only runs when buckets are pipelined: a
        // serial round's wait IS its flight, and recording it would
        // pollute `comm/overlap_ratio` with trivial zeros.
        let mut fold =
            |acc: (crate::comm::transport::TransportStats, u64, u64)| {
                bytes += acc.0.bytes_sent_per_worker;
                hops += acc.0.hops;
                if overlap {
                    flight_ns += acc.1;
                    wait_ns += acc.2;
                }
            };
        if overlap {
            let sp = crate::trace::start();
            self.bucket_begin(workers, plan, 0, maxf)?;
            sp.record(crate::trace::Phase::BucketReduce);
            for b in 1..nb {
                let sp = crate::trace::start();
                self.bucket_begin(workers, plan, b, maxf)?;
                fold(self.bucket_finish(workers, plan, b - 1)?);
                sp.record(crate::trace::Phase::BucketReduce);
            }
            let sp = crate::trace::start();
            fold(self.bucket_finish(workers, plan, nb - 1)?);
            sp.record(crate::trace::Phase::BucketReduce);
        } else {
            for b in 0..nb {
                let sp = crate::trace::start();
                self.bucket_begin(workers, plan, b, maxf)?;
                fold(self.bucket_finish(workers, plan, b)?);
                sp.record(crate::trace::Phase::BucketReduce);
            }
        }
        let inv = 1.0 / n as f32;
        for b in workers.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
        Ok(CommStats {
            bytes_per_worker: bytes,
            payload_floats: layout.total_floats,
            dense_floats: layout.total_floats,
            compression: 1.0,
            residual_norm: 0.0,
            hops,
            overlap_flight_ns: flight_ns,
            overlap_wait_ns: wait_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::RingTransport;
    use crate::util::rng::Rng;

    #[test]
    fn layout_regions_cover_flat_vector() {
        let layout = GradLayout::from_shapes(&[
            vec![4, 6],
            vec![10],
            vec![3, 2],
        ]);
        assert_eq!(layout.total_floats, 24 + 10 + 6);
        assert_eq!(layout.regions[1].offset, 24);
        assert!(!layout.regions[1].is_matrix());
        assert!(layout.regions[2].is_matrix());
        assert_eq!(layout.regions[2].oriented(), (3, 2));
    }

    #[test]
    fn fingerprint_tracks_geometry() {
        let a = GradLayout::from_shapes(&[vec![4, 6], vec![10]]);
        let b = GradLayout::from_shapes(&[vec![4, 6], vec![10]]);
        // Same element count, transposed geometry: must differ.
        let c = GradLayout::from_shapes(&[vec![6, 4], vec![10]]);
        let d = GradLayout::from_shapes(&[vec![4, 6]]);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), d.fingerprint());
    }

    #[test]
    fn factor_floats_cap_at_exact_size() {
        let r = GradRegion { offset: 0, len: 12, rows: 3, cols: 4 };
        // rank beyond the long dim degenerates to an exact transform.
        assert_eq!(r.factor_floats(100), 4 * 3);
        assert_eq!(r.factor_floats(2), 2 * 3);
    }

    #[test]
    fn dense_means_over_workers() {
        let layout = GradLayout::from_shapes(&[vec![5, 2]]);
        let mut c =
            DenseAllReduce::new(Box::new(RingTransport::new(4)));
        let mut rng = Rng::new(3);
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v = vec![0.0f32; 10];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let mut expect = vec![0.0f32; 10];
        for b in &bufs {
            for (e, x) in expect.iter_mut().zip(b) {
                *e += *x / 4.0;
            }
        }
        let stats = c.all_reduce_mean(&mut bufs, &layout).unwrap();
        assert_eq!(stats.payload_floats, 10);
        assert!((stats.compression - 1.0).abs() < 1e-12);
        for b in &bufs {
            for (&got, &want) in b.iter().zip(&expect) {
                assert!((got - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn dense_rejects_bad_shapes() {
        let layout = GradLayout::from_shapes(&[vec![4]]);
        let mut c =
            DenseAllReduce::new(Box::new(RingTransport::new(2)));
        let mut wrong_world = vec![vec![0.0f32; 4]];
        assert!(c.all_reduce_mean(&mut wrong_world, &layout).is_err());
        let mut wrong_len = vec![vec![0.0f32; 3], vec![0.0f32; 3]];
        assert!(c.all_reduce_mean(&mut wrong_len, &layout).is_err());
    }

    fn bucketed_layout() -> GradLayout {
        GradLayout::from_shapes(&[
            vec![64, 32],
            vec![32],
            vec![32, 48],
            vec![48],
            vec![8, 8],
        ])
    }

    #[test]
    fn dense_bucketed_overlap_matches_single_shot_bitwise() {
        // World 2: every chunk sum has exactly two terms, so the
        // bucketed schedule is order-free and must match the
        // single-shot path bitwise, serial AND overlapped.
        let layout = bucketed_layout();
        let plan =
            crate::comm::bucket::BucketPlan::from_layout(&layout, 1);
        assert!(plan.len() > 1, "1 KiB target must split this layout");
        let mk =
            || DenseAllReduce::new(Box::new(RingTransport::new(2)));
        let (mut single, mut serial, mut piped) = (mk(), mk(), mk());
        let mut rng = Rng::new(7);
        for round in 0..3 {
            let bufs: Vec<Vec<f32>> = (0..2)
                .map(|_| {
                    let mut v = vec![0.0f32; layout.total_floats];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            let (mut a, mut b, mut c) =
                (bufs.clone(), bufs.clone(), bufs);
            single.all_reduce_mean(&mut a, &layout).unwrap();
            let sb = serial
                .all_reduce_mean_bucketed(&mut b, &layout, &plan, false)
                .unwrap();
            let ob = piped
                .all_reduce_mean_bucketed(&mut c, &layout, &plan, true)
                .unwrap();
            assert_eq!(a, b, "round {round}: serial bucketed differs");
            assert_eq!(a, c, "round {round}: overlapped differs");
            assert_eq!(sb.overlap_flight_ns, 0, "serial records no overlap");
            assert!(ob.overlap_flight_ns > 0, "overlap records flight");
            assert_eq!(sb.bytes_per_worker, ob.bytes_per_worker);
        }
    }

    #[test]
    fn dense_bucketed_four_workers_integer_grads_bitwise() {
        // At world ≥ 3 bucketing shifts ring chunk ownership, so
        // arbitrary f32 sums may differ in rounding between plans.
        // Small-integer gradients (exact in f32 well below 2^24) make
        // every fold order exact, pinning that bucketing changes ONLY
        // the schedule, never the arithmetic.
        let layout = bucketed_layout();
        let plan =
            crate::comm::bucket::BucketPlan::from_layout(&layout, 1);
        let mk =
            || DenseAllReduce::new(Box::new(RingTransport::new(4)));
        let (mut single, mut piped) = (mk(), mk());
        let mut rng = Rng::new(11);
        let bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                (0..layout.total_floats)
                    .map(|_| (rng.next_u64() % 97) as f32 - 48.0)
                    .collect()
            })
            .collect();
        let (mut a, mut b) = (bufs.clone(), bufs);
        single.all_reduce_mean(&mut a, &layout).unwrap();
        piped
            .all_reduce_mean_bucketed(&mut b, &layout, &plan, true)
            .unwrap();
        assert_eq!(a, b, "integer grads must reduce identically");
    }
}
