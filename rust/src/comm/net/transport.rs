//! [`TcpRingTransport`] — the socket backend of [`Transport`]: this
//! process is ONE rank of an N-rank ring whose other members are peer
//! processes (same or different hosts) reached over the persistent
//! links [`TcpWorld`] established.
//!
//! ## Determinism contract
//!
//! The collective schedule is byte-for-byte the in-process
//! `ring_worker`'s: identical chunk boundaries (`c·len/N`), identical
//! hop order, and identical accumulation order (`own += received`, in
//! ring-arrival order). f32 payloads travel as little-endian bytes —
//! an exact roundtrip — so a TCP world's reduced gradient is bitwise
//! identical to the in-process transport's (pinned in
//! rust/tests/net_props.rs), and training under `--transport tcp`
//! reproduces `--transport inproc` losses exactly. Bucketed rounds
//! change only the granularity: each bucket is one ring round whose
//! frames carry the bucket index in the tag byte, so the schedule —
//! and therefore the result — is the same whether buckets are reduced
//! serially or overlapped with coordinator compute.
//!
//! ## Concurrency shape
//!
//! Two persistent threads per rank, both created once at
//! establishment:
//!
//! * `net-recv-{rank}` owns the upstream (recv) stream and decodes
//!   frames into a bounded channel, so every rank's inbound bytes are
//!   ALWAYS being drained and a blocking send can never wedge the ring;
//! * `net-drive-{rank}` owns the downstream (send) stream and runs the
//!   hop loops: the coordinator enqueues jobs (reduce round, f64
//!   sidecar gather, byte-block gather) on a bounded channel and
//!   collects results from per-type completion channels. Synchronous
//!   calls are enqueue + wait; [`Transport::reduce_begin`] /
//!   [`Transport::reduce_finish`] are the same two halves split apart,
//!   which is what lets bucketed reduction overlap wire time with
//!   coordinator compute on a real network — without per-round thread
//!   spawns, and with every buffer ping-ponging through the channels
//!   so steady-state rounds reuse the same few allocations.
//!
//! Failures never panic the process: a dead peer surfaces as
//! `peer-disconnected`/`truncated-frame`, a hung one as `peer-timeout`,
//! cross-talk as `unexpected-rank`/`round-mismatch`, a divergent bucket
//! schedule as `bucket-out-of-order`, and a mismatched `--wire` as
//! `unknown-wire-codec`/`quantized-payload-mismatch` — all typed
//! [`NetError`]s carried through `anyhow` with rank/round context.

use std::collections::VecDeque;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::comm::codec::WireCodec;
use crate::comm::transport::{Transport, TransportStats};

use super::wire::{
    encode_frame_tagged, read_frame, FrameHeader, FrameKind, NetError,
};
use super::world::{TcpWorld, WorldConfig};

/// The socket [`Transport`]: `world_size()` ranks across processes,
/// exactly one of which (`local_endpoints() == 1`) lives here.
pub struct TcpRingTransport {
    world: usize,
    rank: usize,
    /// The persistent `net-drive-{rank}` thread; `None` for a world of
    /// 1, whose rounds are local no-ops.
    driver: Option<DriverHandle>,
    /// Pending local no-op rounds for the degenerate world of 1 (the
    /// serial begin/finish path still runs there).
    local_reduces: Mutex<VecDeque<Vec<Vec<f32>>>>,
    local_gathers: Mutex<VecDeque<Vec<Vec<u8>>>>,
    /// Outer-shell pool for routing the synchronous `all_reduce_sum`
    /// through the driver without per-round allocations.
    shells: Mutex<VecDeque<Vec<Vec<f32>>>>,
    /// (local, out) f64 scratch pairs for the sidecar gather.
    f64_scratch: Mutex<VecDeque<(Vec<f64>, Vec<f64>)>>,
}

/// One queued unit of wire work for the driver thread.
enum DriverJob {
    /// A full two-phase ring all-reduce of one buffer; `tag` is the
    /// bucket index stamped on every Data frame (0 when unbucketed).
    Reduce { bufs: Vec<Vec<f32>>, tag: u8 },
    /// Ring relay of the f64 loss sidecar.
    GatherF64 { local: Vec<f64>, out: Vec<f64> },
    /// Ring relay of rank-ordered opaque byte blocks; `codec_tag` is
    /// the wire-codec id stamped on every Gather frame.
    GatherBytes { blocks: Vec<Vec<u8>>, codec_tag: u8 },
}

struct DriverHandle {
    /// Dropping this (`Drop` takes it) closes the queue and stops the
    /// driver. Capacity 4 covers the depth-2 bucket pipeline plus a
    /// queued sidecar op with room to spare.
    jobs: Option<SyncSender<DriverJob>>,
    reduce_done: Receiver<Result<(Vec<Vec<f32>>, TransportStats)>>,
    gather_done: Receiver<Result<(Vec<f64>, Vec<f64>, usize)>>,
    bytes_done: Receiver<Result<(Vec<Vec<u8>>, usize)>>,
    handle: Option<JoinHandle<()>>,
}

impl DriverHandle {
    fn send_job(&self, job: DriverJob) -> Result<()> {
        let Some(tx) = &self.jobs else {
            bail!("net driver stopped");
        };
        tx.send(job).map_err(|_| anyhow!("net driver gone"))
    }
}

struct TcpState {
    /// Downstream link (to rank+1).
    send: Option<TcpStream>,
    /// Upstream link, owned by the reader thread.
    reader: Option<ReaderLink>,
    /// Encoded-frame scratch (header + payload + crc), reused per hop.
    frame: Vec<u8>,
    /// Outgoing payload byte scratch, reused per hop.
    payload: Vec<u8>,
    /// Collective round counter; every frame carries it and every
    /// received frame must match it (lockstep check). Bucketed steps
    /// advance it once per bucket — deterministically, so every rank
    /// counts in lockstep.
    round: u64,
    io_timeout: Duration,
}

struct ReaderLink {
    frames: Receiver<Result<(FrameHeader, Vec<u8>), NetError>>,
    recycle: SyncSender<Vec<u8>>,
    /// Clone of the recv stream: shutdown unblocks the reader's
    /// blocking read at teardown.
    shutdown: TcpStream,
    handle: Option<JoinHandle<()>>,
}

/// The reader thread: decode frames off the upstream stream forever,
/// reusing payload buffers returned through the recycle channel. Exits
/// on any decode error (forwarded to the coordinator) or when the
/// coordinator goes away.
fn reader_loop(
    mut stream: TcpStream,
    tx: SyncSender<Result<(FrameHeader, Vec<u8>), NetError>>,
    recycle: Receiver<Vec<u8>>,
) {
    loop {
        let mut payload = recycle.try_recv().unwrap_or_default();
        match read_frame(&mut stream, &mut payload) {
            Ok(hdr) => {
                if tx.send(Ok((hdr, payload))).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Err(e));
                return;
            }
        }
    }
}

/// Stage f32s as little-endian payload bytes (exact roundtrip).
fn stage_f32(out: &mut Vec<u8>, vals: &[f32]) {
    out.clear();
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn stage_f64(out: &mut Vec<u8>, vals: &[f64]) {
    out.clear();
    out.reserve(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn stage_bytes(out: &mut Vec<u8>, vals: &[u8]) {
    out.clear();
    out.extend_from_slice(vals);
}

impl TcpState {
    /// Frame and send the staged payload. Returns real wire bytes
    /// (header + payload + crc) — what the comm metrics record.
    fn send_staged(
        &mut self,
        rank: u32,
        kind: FrameKind,
        tag: u8,
        round: u64,
    ) -> Result<usize, NetError> {
        use std::io::Write;
        // NetSend span: encode + the blocking socket write. Error paths
        // skip the record — a failed round tears the run down anyway.
        let sp = crate::trace::start();
        let total = encode_frame_tagged(
            &mut self.frame,
            kind,
            tag,
            rank,
            round,
            &self.payload,
        )?;
        let stream = self.send.as_mut().ok_or(NetError::PeerDisconnected)?;
        stream.write_all(&self.frame)?;
        sp.record(crate::trace::Phase::NetSend);
        Ok(total)
    }

    /// Receive one frame and validate its provenance: kind, upstream
    /// rank, lockstep round, and (when `needed` is given) exact payload
    /// size. Returns the frame's tag byte alongside the payload; tag
    /// semantics are kind-specific, so callers validate it.
    fn recv_expect(
        &mut self,
        kind: FrameKind,
        from: u32,
        round: u64,
        needed: Option<usize>,
    ) -> Result<(u8, Vec<u8>), NetError> {
        let link = self.reader.as_ref().ok_or(NetError::PeerDisconnected)?;
        // NetRecv span: the blocking wait for the upstream frame — the
        // ring's exposed-latency phase (validation below is ns-scale).
        let sp = crate::trace::start();
        let res = match link.frames.recv_timeout(self.io_timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => return Err(NetError::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                return Err(NetError::PeerDisconnected)
            }
        };
        let (hdr, payload) = res?;
        sp.record(crate::trace::Phase::NetRecv);
        if hdr.kind != kind {
            return Err(NetError::UnexpectedKind { expected: kind, got: hdr.kind });
        }
        if hdr.rank != from {
            return Err(NetError::UnexpectedRank { expected: from, got: hdr.rank });
        }
        if hdr.round != round {
            return Err(NetError::RoundMismatch { expected: round, got: hdr.round });
        }
        if let Some(needed) = needed {
            if payload.len() != needed {
                return Err(NetError::Truncated { needed, got: payload.len() });
            }
        }
        Ok((hdr.tag, payload))
    }

    /// Hand a consumed payload buffer back to the reader for reuse.
    fn recycle(&mut self, payload: Vec<u8>) {
        if let Some(link) = &self.reader {
            let _ = link.recycle.try_send(payload);
        }
    }

    /// One two-phase ring all-reduce round over `buf`; every frame is
    /// stamped with the bucket `tag`, and a frame whose tag disagrees
    /// is the typed `bucket-out-of-order` failure.
    fn run_reduce(
        &mut self,
        world: usize,
        rank: usize,
        buf: &mut [f32],
        tag: u8,
    ) -> Result<usize> {
        let round = self.round;
        self.round += 1;
        let n = world;
        let prev = ((rank + n - 1) % n) as u32;
        let len = buf.len();
        // Chunk boundaries: identical to the in-process ring_worker.
        let start = |c: usize| c * len / n;
        let mut sent = 0usize;
        // Phase 1: reduce-scatter (add order identical to ring_worker —
        // own chunk += received chunk, in ring-arrival order).
        for step in 0..n - 1 {
            let send_chunk = (rank + n - step) % n;
            let (s0, s1) = (start(send_chunk), start(send_chunk + 1));
            stage_f32(&mut self.payload, &buf[s0..s1]);
            sent += self
                .send_staged(rank as u32, FrameKind::Data, tag, round)
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} send: {e}")
                })?;
            let recv_chunk = (rank + n - step - 1 + n) % n;
            let (r0, r1) = (start(recv_chunk), start(recv_chunk + 1));
            let (got_tag, data) = self
                .recv_expect(FrameKind::Data, prev, round, Some((r1 - r0) * 4))
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} recv: {e}")
                })?;
            if got_tag != tag {
                return Err(anyhow!(
                    "tcp ring rank {rank} round {round} recv: {}",
                    NetError::BucketOutOfOrder { expected: tag, got: got_tag }
                ));
            }
            for (dst, src) in buf[r0..r1].iter_mut().zip(data.chunks_exact(4))
            {
                // repo-lint: allow(net-panic) — chunks_exact(4) yields
                // exactly-4-byte slices; recv_expect validated length.
                *dst += f32::from_le_bytes(src.try_into().unwrap());
            }
            self.recycle(data);
        }
        // Phase 2: all-gather.
        for step in 0..n - 1 {
            let send_chunk = (rank + 1 + n - step) % n;
            let (s0, s1) = (start(send_chunk), start(send_chunk + 1));
            stage_f32(&mut self.payload, &buf[s0..s1]);
            sent += self
                .send_staged(rank as u32, FrameKind::Data, tag, round)
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} send: {e}")
                })?;
            let recv_chunk = (rank + n - step) % n;
            let (r0, r1) = (start(recv_chunk), start(recv_chunk + 1));
            let (got_tag, data) = self
                .recv_expect(FrameKind::Data, prev, round, Some((r1 - r0) * 4))
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} recv: {e}")
                })?;
            if got_tag != tag {
                return Err(anyhow!(
                    "tcp ring rank {rank} round {round} recv: {}",
                    NetError::BucketOutOfOrder { expected: tag, got: got_tag }
                ));
            }
            for (dst, src) in buf[r0..r1].iter_mut().zip(data.chunks_exact(4))
            {
                // repo-lint: allow(net-panic) — chunks_exact(4) yields
                // exactly-4-byte slices; recv_expect validated length.
                *dst = f32::from_le_bytes(src.try_into().unwrap());
            }
            self.recycle(data);
        }
        Ok(sent)
    }

    /// Ring relay of the f64 sidecar into rank-ordered `out`.
    fn run_gather_f64(
        &mut self,
        world: usize,
        rank: usize,
        local: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<usize> {
        let n = world;
        let l = local.len();
        out.clear();
        out.resize(n * l, 0.0);
        out[rank * l..(rank + 1) * l].copy_from_slice(local);
        let round = self.round;
        self.round += 1;
        let prev = ((rank + n - 1) % n) as u32;
        let mut sent = 0usize;
        for step in 0..n - 1 {
            // Relay: first hop sends our own slot, hop s forwards the
            // slot received at hop s-1.
            let send_idx = (rank + n - step) % n;
            stage_f64(&mut self.payload, &out[send_idx * l..(send_idx + 1) * l]);
            sent += self
                .send_staged(rank as u32, FrameKind::Gather, 0, round)
                .map_err(|e| {
                    anyhow!("tcp gather rank {rank} round {round} send: {e}")
                })?;
            let recv_idx = (rank + n - step - 1) % n;
            let (_tag, data) = self
                .recv_expect(FrameKind::Gather, prev, round, Some(l * 8))
                .map_err(|e| {
                    anyhow!("tcp gather rank {rank} round {round} recv: {e}")
                })?;
            for (dst, src) in out[recv_idx * l..(recv_idx + 1) * l]
                .iter_mut()
                .zip(data.chunks_exact(8))
            {
                // repo-lint: allow(net-panic) — chunks_exact(8) yields
                // exactly-8-byte slices; recv_expect validated length.
                *dst = f64::from_le_bytes(src.try_into().unwrap());
            }
            self.recycle(data);
        }
        Ok(sent)
    }

    /// Ring relay of rank-ordered opaque byte blocks (quantized
    /// factors). Every frame carries the wire-codec id in its tag; a
    /// tag outside the codec vocabulary is `unknown-wire-codec`, and a
    /// block whose codec or byte count disagrees with ours is
    /// `quantized-payload-mismatch`.
    fn run_gather_bytes(
        &mut self,
        world: usize,
        rank: usize,
        blocks: &mut [Vec<u8>],
        codec_tag: u8,
    ) -> Result<usize> {
        let n = world;
        let round = self.round;
        self.round += 1;
        let prev = ((rank + n - 1) % n) as u32;
        let needed = blocks[rank].len();
        let mut sent = 0usize;
        for step in 0..n - 1 {
            let send_idx = (rank + n - step) % n;
            stage_bytes(&mut self.payload, &blocks[send_idx]);
            sent += self
                .send_staged(rank as u32, FrameKind::Gather, codec_tag, round)
                .map_err(|e| {
                    anyhow!("tcp bgather rank {rank} round {round} send: {e}")
                })?;
            let recv_idx = (rank + n - step - 1) % n;
            let (got_tag, data) = self
                .recv_expect(FrameKind::Gather, prev, round, None)
                .map_err(|e| {
                    anyhow!("tcp bgather rank {rank} round {round} recv: {e}")
                })?;
            if WireCodec::from_tag(got_tag).is_none() {
                return Err(anyhow!(
                    "tcp bgather rank {rank} round {round} recv: {}",
                    NetError::UnknownWireCodec(got_tag)
                ));
            }
            if got_tag != codec_tag || data.len() != needed {
                return Err(anyhow!(
                    "tcp bgather rank {rank} round {round} recv: {}",
                    NetError::QuantizedPayloadMismatch {
                        expected: needed,
                        got: data.len(),
                    }
                ));
            }
            stage_bytes(&mut blocks[recv_idx], &data);
            self.recycle(data);
        }
        Ok(sent)
    }
}

/// The driver thread body: run queued wire work until the job channel
/// closes, then tear the links down (so `Drop` on the transport is
/// just close-queue + join).
fn driver_loop(
    mut st: TcpState,
    world: usize,
    rank: usize,
    jobs: Receiver<DriverJob>,
    reduce_tx: SyncSender<Result<(Vec<Vec<f32>>, TransportStats)>>,
    gather_tx: SyncSender<Result<(Vec<f64>, Vec<f64>, usize)>>,
    bytes_tx: SyncSender<Result<(Vec<Vec<u8>>, usize)>>,
) {
    while let Ok(job) = jobs.recv() {
        let delivered = match job {
            DriverJob::Reduce { mut bufs, tag } => {
                let res = match bufs.first_mut() {
                    Some(buf) => st.run_reduce(world, rank, buf, tag),
                    None => Err(anyhow!("reduce job without a buffer")),
                };
                let out = res.map(|sent| {
                    (
                        bufs,
                        TransportStats {
                            bytes_sent_per_worker: sent,
                            hops: 2 * (world - 1),
                        },
                    )
                });
                reduce_tx.send(out).is_ok()
            }
            DriverJob::GatherF64 { local, mut out } => {
                let res = st.run_gather_f64(world, rank, &local, &mut out);
                gather_tx.send(res.map(|sent| (local, out, sent))).is_ok()
            }
            DriverJob::GatherBytes { mut blocks, codec_tag } => {
                let res =
                    st.run_gather_bytes(world, rank, &mut blocks, codec_tag);
                bytes_tx.send(res.map(|sent| (blocks, sent))).is_ok()
            }
        };
        if !delivered {
            break;
        }
    }
    // Teardown: unblock + join the reader, close the send stream.
    if let Some(s) = st.send.take() {
        let _ = s.shutdown(Shutdown::Both);
    }
    if let Some(link) = st.reader.take() {
        let ReaderLink { frames, recycle, shutdown, handle } = link;
        let _ = shutdown.shutdown(Shutdown::Both);
        drop(frames);
        drop(recycle);
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl TcpRingTransport {
    /// Bind/dial/handshake the world, spawn the persistent reader and
    /// driver threads, and run the round-0 liveness probe through the
    /// data path. Returns only when this rank is ready for gradient
    /// rounds.
    pub fn establish(cfg: &WorldConfig) -> Result<TcpRingTransport> {
        let (rank, world) = (cfg.net.rank, cfg.net.world);
        let tw = TcpWorld::establish(cfg).map_err(|e| {
            anyhow!("establish tcp world (rank {rank} of {world}): {e}")
        })?;
        let t = TcpRingTransport::from_world(tw, cfg.io_timeout)?;
        t.probe()?;
        Ok(t)
    }

    fn from_world(
        w: TcpWorld,
        io_timeout: Duration,
    ) -> Result<TcpRingTransport> {
        let driver = if w.world > 1 {
            if let Some(s) = &w.send {
                s.set_write_timeout(Some(io_timeout))?;
            }
            let reader = match w.recv {
                None => None,
                Some(stream) => {
                    // The reader blocks in read() between rounds (no
                    // frame is due); liveness while one IS due is
                    // enforced by the driver's recv_timeout instead.
                    stream.set_read_timeout(None)?;
                    let shutdown = stream.try_clone()?;
                    let (tx, frames) = sync_channel(2);
                    let (recycle, recycle_rx) = sync_channel::<Vec<u8>>(2);
                    let handle = std::thread::Builder::new()
                        .name(format!("net-recv-{}", w.rank))
                        .spawn(move || reader_loop(stream, tx, recycle_rx))
                        // repo-lint: allow(net-panic) — local thread-spawn
                        // resource exhaustion, not peer-controlled input.
                        .expect("spawn net reader");
                    Some(ReaderLink {
                        frames,
                        recycle,
                        shutdown,
                        handle: Some(handle),
                    })
                }
            };
            let st = TcpState {
                send: w.send,
                reader,
                frame: Vec::new(),
                payload: Vec::new(),
                round: 0,
                io_timeout,
            };
            let (jobs_tx, jobs_rx) = sync_channel::<DriverJob>(4);
            let (reduce_tx, reduce_done) = sync_channel(2);
            let (gather_tx, gather_done) = sync_channel(2);
            let (bytes_tx, bytes_done) = sync_channel(2);
            let (world, rank) = (w.world, w.rank);
            let handle = std::thread::Builder::new()
                .name(format!("net-drive-{rank}"))
                .spawn(move || {
                    driver_loop(
                        st, world, rank, jobs_rx, reduce_tx, gather_tx,
                        bytes_tx,
                    )
                })
                // repo-lint: allow(net-panic) — local thread-spawn
                // resource exhaustion, not peer-controlled input.
                .expect("spawn net driver");
            Some(DriverHandle {
                jobs: Some(jobs_tx),
                reduce_done,
                gather_done,
                bytes_done,
                handle: Some(handle),
            })
        } else {
            None
        };
        Ok(TcpRingTransport {
            world: w.world,
            rank: w.rank,
            driver,
            local_reduces: Mutex::new(VecDeque::new()),
            local_gathers: Mutex::new(VecDeque::new()),
            shells: Mutex::new(VecDeque::new()),
            f64_scratch: Mutex::new(VecDeque::new()),
        })
    }

    /// This process's world rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Round 0: all-reduce a single 1.0 through the ring. Every rank
    /// must see exactly `world` — a cheap end-to-end check that the
    /// whole ring is connected and counting the same world before the
    /// first gradient round.
    fn probe(&self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let mut bufs = vec![vec![1.0f32]];
        self.all_reduce_sum(&mut bufs)?;
        let sum = bufs[0][0];
        if (sum - self.world as f32).abs() > 0.25 {
            return Err(anyhow!(
                "ring probe: {}",
                NetError::WorldSizeMismatch {
                    ours: self.world as u32,
                    theirs: sum.round() as u32,
                }
            ));
        }
        Ok(())
    }

    fn driver(&self) -> Result<&DriverHandle> {
        match &self.driver {
            Some(d) => Ok(d),
            None => bail!("net driver only exists for worlds > 1"),
        }
    }

    fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
        // A poisoning panic already failed the run; the pools are still
        // structurally sound for cleanup.
        m.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl Transport for TcpRingTransport {
    fn world_size(&self) -> usize {
        self.world
    }

    fn local_endpoints(&self) -> usize {
        1
    }

    fn rank_offset(&self) -> usize {
        self.rank
    }

    fn supports_overlap(&self) -> bool {
        self.world > 1
    }

    fn all_reduce_sum(&self, buffers: &mut [Vec<f32>]) -> Result<TransportStats> {
        assert_eq!(buffers.len(), 1, "a tcp rank owns exactly one buffer");
        if self.world == 1 {
            return Ok(TransportStats { bytes_sent_per_worker: 0, hops: 0 });
        }
        let d = self.driver()?;
        let mut shell =
            Self::lock(&self.shells).pop_front().unwrap_or_default();
        shell.push(std::mem::take(&mut buffers[0]));
        d.send_job(DriverJob::Reduce { bufs: shell, tag: 0 })?;
        let Ok(res) = d.reduce_done.recv() else {
            bail!("net driver gone");
        };
        let (mut bufs, stats) = res?;
        buffers[0] = bufs.pop().unwrap_or_default();
        Self::lock(&self.shells).push_back(bufs);
        Ok(stats)
    }

    fn reduce_begin(&self, buffers: Vec<Vec<f32>>, tag: u8) -> Result<()> {
        if self.world == 1 {
            Self::lock(&self.local_reduces).push_back(buffers);
            return Ok(());
        }
        self.driver()?.send_job(DriverJob::Reduce { bufs: buffers, tag })
    }

    fn reduce_finish(&self) -> Result<(Vec<Vec<f32>>, TransportStats)> {
        if self.world == 1 {
            let Some(bufs) = Self::lock(&self.local_reduces).pop_front()
            else {
                bail!("reduce_finish without a matching reduce_begin");
            };
            return Ok((
                bufs,
                TransportStats { bytes_sent_per_worker: 0, hops: 0 },
            ));
        }
        let d = self.driver()?;
        let Ok(res) = d.reduce_done.recv() else {
            bail!("net driver gone");
        };
        res
    }

    /// Ring all-gather of the loss sidecar: on return `out` holds every
    /// rank's `local` values in rank order — the exact fold order the
    /// in-process trainer uses, so loss series match bitwise. Returns
    /// the real wire bytes this rank sent for the sidecar.
    fn all_gather_f64(
        &self,
        local: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<usize> {
        if self.world == 1 {
            out.clear();
            out.extend_from_slice(local);
            return Ok(0);
        }
        let d = self.driver()?;
        let (mut local_v, out_v) =
            Self::lock(&self.f64_scratch).pop_front().unwrap_or_default();
        local_v.clear();
        local_v.extend_from_slice(local);
        d.send_job(DriverJob::GatherF64 { local: local_v, out: out_v })?;
        let Ok(res) = d.gather_done.recv() else {
            bail!("net driver gone");
        };
        let (local_v, out_v, sent) = res?;
        out.clear();
        out.extend_from_slice(&out_v);
        Self::lock(&self.f64_scratch).push_back((local_v, out_v));
        Ok(sent)
    }

    fn all_gather_bytes(
        &self,
        blocks: &mut Vec<Vec<u8>>,
        tag: u8,
    ) -> Result<usize> {
        if blocks.len() != self.world {
            bail!(
                "all_gather_bytes: {} blocks for a world of {}",
                blocks.len(),
                self.world
            );
        }
        if self.world == 1 {
            return Ok(0);
        }
        let d = self.driver()?;
        let owned = std::mem::take(blocks);
        d.send_job(DriverJob::GatherBytes { blocks: owned, codec_tag: tag })?;
        let Ok(res) = d.bytes_done.recv() else {
            bail!("net driver gone");
        };
        let (owned, sent) = res?;
        *blocks = owned;
        Ok(sent)
    }

    fn gather_bytes_begin(&self, blocks: Vec<Vec<u8>>, tag: u8) -> Result<()> {
        if blocks.len() != self.world {
            bail!(
                "gather_bytes_begin: {} blocks for a world of {}",
                blocks.len(),
                self.world
            );
        }
        if self.world == 1 {
            Self::lock(&self.local_gathers).push_back(blocks);
            return Ok(());
        }
        self.driver()?
            .send_job(DriverJob::GatherBytes { blocks, codec_tag: tag })
    }

    fn gather_bytes_finish(&self) -> Result<(Vec<Vec<u8>>, usize)> {
        if self.world == 1 {
            let Some(blocks) = Self::lock(&self.local_gathers).pop_front()
            else {
                bail!("gather_bytes_finish without a matching begin");
            };
            return Ok((blocks, 0));
        }
        let d = self.driver()?;
        let Ok(res) = d.bytes_done.recv() else {
            bail!("net driver gone");
        };
        res
    }
}

impl Drop for TcpRingTransport {
    fn drop(&mut self) {
        if let Some(mut d) = self.driver.take() {
            // Closing the job queue stops the driver, which tears down
            // the streams and joins the reader on its way out.
            d.jobs.take();
            if let Some(h) = d.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::net::world::NetConfig;
    use crate::comm::RingTransport;

    fn free_peers(n: usize) -> Vec<String> {
        crate::comm::net::launch::free_loopback_peers(n).unwrap()
    }

    fn world_cfg(world: usize, rank: usize, peers: Vec<String>) -> WorldConfig {
        let mut cfg = WorldConfig::new(
            NetConfig { world, rank, peers },
            0xBA5E,
            0x1A40,
        );
        cfg.connect_timeout = Duration::from_secs(5);
        cfg.io_timeout = Duration::from_secs(5);
        cfg
    }

    /// Stand up a full loopback world, run `rounds` all-reduces per
    /// rank, and return every rank's final buffer.
    fn run_world(
        world: usize,
        seeds: &[Vec<f32>],
        rounds: usize,
    ) -> Vec<Vec<f32>> {
        let peers = free_peers(world);
        let mut handles = Vec::new();
        for rank in 0..world {
            let cfg = world_cfg(world, rank, peers.clone());
            let mut buf = seeds[rank].clone();
            handles.push(std::thread::spawn(move || {
                let t = TcpRingTransport::establish(&cfg).unwrap();
                for _ in 0..rounds {
                    let mut bufs = vec![std::mem::take(&mut buf)];
                    t.all_reduce_sum(&mut bufs).unwrap();
                    buf = bufs.pop().unwrap();
                }
                buf
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn loopback_world_sums_bitwise_like_inproc() {
        let n = 3;
        let seeds: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..37).map(|i| (r * 100 + i) as f32 * 0.25).collect())
            .collect();
        let mut inproc = seeds.clone();
        RingTransport::new(n).all_reduce_sum(&mut inproc).unwrap();
        let tcp = run_world(n, &seeds, 1);
        for r in 0..n {
            assert_eq!(tcp[r], inproc[r], "rank {r} diverged");
        }
    }

    #[test]
    fn world_one_is_local_noop() {
        let cfg = world_cfg(1, 0, vec!["127.0.0.1:1".into()]);
        let t = TcpRingTransport::establish(&cfg).unwrap();
        assert_eq!(t.world_size(), 1);
        assert_eq!(t.local_endpoints(), 1);
        assert!(!t.supports_overlap());
        let mut bufs = vec![vec![2.0f32, 3.0]];
        let stats = t.all_reduce_sum(&mut bufs).unwrap();
        assert_eq!(stats.hops, 0);
        assert_eq!(bufs[0], vec![2.0, 3.0]);
        let mut out = Vec::new();
        t.all_gather_f64(&[1.25, 2.5], &mut out).unwrap();
        assert_eq!(out, vec![1.25, 2.5]);
        // Serial begin/finish still round-trips in a world of 1.
        t.reduce_begin(vec![vec![7.0f32]], 0).unwrap();
        let (got, _) = t.reduce_finish().unwrap();
        assert_eq!(got, vec![vec![7.0f32]]);
        t.gather_bytes_begin(vec![vec![1u8, 2]], 1).unwrap();
        let (blocks, sent) = t.gather_bytes_finish().unwrap();
        assert_eq!(blocks, vec![vec![1u8, 2]]);
        assert_eq!(sent, 0);
    }

    #[test]
    fn gather_orders_by_rank() {
        let n = 3;
        let peers = free_peers(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let cfg = world_cfg(n, rank, peers.clone());
            handles.push(std::thread::spawn(move || {
                let t = TcpRingTransport::establish(&cfg).unwrap();
                let local = [rank as f64 * 10.0, rank as f64 * 10.0 + 1.0];
                let mut out = Vec::new();
                t.all_gather_f64(&local, &mut out).unwrap();
                out
            }));
        }
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        }
    }

    #[test]
    fn overlapped_tcp_rounds_match_sync_bitwise() {
        // Two bucketed rounds in flight per rank (begin/begin/finish/
        // finish) must equal two back-to-back sync rounds.
        let n = 2;
        let seeds: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..23).map(|i| (r * 31 + i) as f32 * 0.5).collect())
            .collect();
        let expect = {
            let mut bufs = seeds.clone();
            RingTransport::new(n).all_reduce_sum(&mut bufs).unwrap();
            bufs
        };
        let peers = free_peers(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let cfg = world_cfg(n, rank, peers.clone());
            let a = seeds[rank][..11].to_vec();
            let b = seeds[rank][11..].to_vec();
            handles.push(std::thread::spawn(move || {
                let t = TcpRingTransport::establish(&cfg).unwrap();
                assert!(t.supports_overlap());
                t.reduce_begin(vec![a], 0).unwrap();
                t.reduce_begin(vec![b], 1).unwrap();
                let (mut got_a, stats) = t.reduce_finish().unwrap();
                let (mut got_b, _) = t.reduce_finish().unwrap();
                assert_eq!(stats.hops, 2 * (n - 1));
                let mut joined = got_a.pop().unwrap();
                joined.extend_from_slice(&got_b.pop().unwrap());
                joined
            }));
        }
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got, expect[0], "bucketed tcp diverged from sync");
        }
    }

    #[test]
    fn byte_gather_orders_by_rank_with_codec_tag() {
        let n = 3;
        let peers = free_peers(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let cfg = world_cfg(n, rank, peers.clone());
            handles.push(std::thread::spawn(move || {
                let t = TcpRingTransport::establish(&cfg).unwrap();
                assert_eq!(t.rank_offset(), rank);
                let mut blocks: Vec<Vec<u8>> =
                    (0..n).map(|_| Vec::new()).collect();
                blocks[rank] = vec![rank as u8; 5];
                let sent =
                    t.all_gather_bytes(&mut blocks, WireCodec::Bf16.tag())
                        .unwrap();
                assert!(sent > 0);
                blocks
            }));
        }
        for h in handles {
            let got = h.join().unwrap();
            for (r, b) in got.iter().enumerate() {
                assert_eq!(b, &vec![r as u8; 5], "rank {r} block");
            }
        }
    }
}
