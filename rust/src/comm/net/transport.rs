//! [`TcpRingTransport`] — the socket backend of [`Transport`]: this
//! process is ONE rank of an N-rank ring whose other members are peer
//! processes (same or different hosts) reached over the persistent
//! links [`TcpWorld`] established.
//!
//! ## Determinism contract
//!
//! The collective schedule is byte-for-byte the in-process
//! `ring_worker`'s: identical chunk boundaries (`c·len/N`), identical
//! hop order, and identical accumulation order (`own += received`, in
//! ring-arrival order). f32 payloads travel as little-endian bytes —
//! an exact roundtrip — so a TCP world's reduced gradient is bitwise
//! identical to the in-process transport's (pinned in
//! rust/tests/net_props.rs), and training under `--transport tcp`
//! reproduces `--transport inproc` losses exactly.
//!
//! ## Concurrency shape
//!
//! One persistent reader thread per rank owns the upstream (recv)
//! stream and decodes frames into a bounded channel; the coordinator
//! thread writes to the downstream (send) stream and consumes decoded
//! frames. This keeps the classic ring deadlock away — every rank's
//! inbound bytes are ALWAYS being drained, so a blocking send can never
//! wedge the whole ring — without per-round thread spawns (the reader
//! is created once, like the pool and ring workers). Payload buffers
//! ping-pong between the reader and the coordinator through a recycle
//! channel, so steady-state rounds reuse the same few allocations.
//!
//! Failures never panic the process: a dead peer surfaces as
//! `peer-disconnected`/`truncated-frame`, a hung one as `peer-timeout`,
//! cross-talk as `unexpected-rank`/`round-mismatch` — all typed
//! [`NetError`]s carried through `anyhow` with rank/round context.

use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Mutex;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::comm::transport::{Transport, TransportStats};

use super::wire::{encode_frame, read_frame, FrameHeader, FrameKind, NetError};
use super::world::{TcpWorld, WorldConfig};

/// The socket [`Transport`]: `world_size()` ranks across processes,
/// exactly one of which (`local_endpoints() == 1`) lives here.
pub struct TcpRingTransport {
    world: usize,
    rank: usize,
    state: Mutex<TcpState>,
}

struct TcpState {
    /// Downstream link (to rank+1); `None` for a world of 1.
    send: Option<TcpStream>,
    /// Upstream link, owned by the reader thread.
    reader: Option<ReaderLink>,
    /// Encoded-frame scratch (header + payload + crc), reused per hop.
    frame: Vec<u8>,
    /// Outgoing payload byte scratch, reused per hop.
    payload: Vec<u8>,
    /// Collective round counter; every frame carries it and every
    /// received frame must match it (lockstep check).
    round: u64,
    io_timeout: Duration,
}

struct ReaderLink {
    frames: Receiver<Result<(FrameHeader, Vec<u8>), NetError>>,
    recycle: SyncSender<Vec<u8>>,
    /// Clone of the recv stream: `Drop` shuts it down to unblock the
    /// reader's blocking read.
    shutdown: TcpStream,
    handle: Option<JoinHandle<()>>,
}

/// The reader thread: decode frames off the upstream stream forever,
/// reusing payload buffers returned through the recycle channel. Exits
/// on any decode error (forwarded to the coordinator) or when the
/// coordinator goes away.
fn reader_loop(
    mut stream: TcpStream,
    tx: SyncSender<Result<(FrameHeader, Vec<u8>), NetError>>,
    recycle: Receiver<Vec<u8>>,
) {
    loop {
        let mut payload = recycle.try_recv().unwrap_or_default();
        match read_frame(&mut stream, &mut payload) {
            Ok(hdr) => {
                if tx.send(Ok((hdr, payload))).is_err() {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Err(e));
                return;
            }
        }
    }
}

/// Stage f32s as little-endian payload bytes (exact roundtrip).
fn stage_f32(out: &mut Vec<u8>, vals: &[f32]) {
    out.clear();
    out.reserve(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn stage_f64(out: &mut Vec<u8>, vals: &[f64]) {
    out.clear();
    out.reserve(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

impl TcpState {
    /// Frame and send the staged payload. Returns real wire bytes
    /// (header + payload + crc) — what the comm metrics record.
    fn send_staged(
        &mut self,
        rank: u32,
        kind: FrameKind,
        round: u64,
    ) -> Result<usize, NetError> {
        use std::io::Write;
        // NetSend span: encode + the blocking socket write. Error paths
        // skip the record — a failed round tears the run down anyway.
        let sp = crate::trace::start();
        let total =
            encode_frame(&mut self.frame, kind, rank, round, &self.payload)?;
        let stream = self.send.as_mut().ok_or(NetError::PeerDisconnected)?;
        stream.write_all(&self.frame)?;
        sp.record(crate::trace::Phase::NetSend);
        Ok(total)
    }

    /// Receive one frame and validate its provenance: kind, upstream
    /// rank, lockstep round, and exact payload size.
    fn recv_expect(
        &mut self,
        kind: FrameKind,
        from: u32,
        round: u64,
        needed: usize,
    ) -> Result<Vec<u8>, NetError> {
        let link = self.reader.as_ref().ok_or(NetError::PeerDisconnected)?;
        // NetRecv span: the blocking wait for the upstream frame — the
        // ring's exposed-latency phase (validation below is ns-scale).
        let sp = crate::trace::start();
        let res = match link.frames.recv_timeout(self.io_timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => return Err(NetError::Timeout),
            Err(RecvTimeoutError::Disconnected) => {
                return Err(NetError::PeerDisconnected)
            }
        };
        let (hdr, payload) = res?;
        sp.record(crate::trace::Phase::NetRecv);
        if hdr.kind != kind {
            return Err(NetError::UnexpectedKind { expected: kind, got: hdr.kind });
        }
        if hdr.rank != from {
            return Err(NetError::UnexpectedRank { expected: from, got: hdr.rank });
        }
        if hdr.round != round {
            return Err(NetError::RoundMismatch { expected: round, got: hdr.round });
        }
        if payload.len() != needed {
            return Err(NetError::Truncated { needed, got: payload.len() });
        }
        Ok(payload)
    }

    /// Hand a consumed payload buffer back to the reader for reuse.
    fn recycle(&mut self, payload: Vec<u8>) {
        if let Some(link) = &self.reader {
            let _ = link.recycle.try_send(payload);
        }
    }
}

impl TcpRingTransport {
    /// Bind/dial/handshake the world, spawn the persistent reader, and
    /// run the round-0 liveness probe through the data path. Returns
    /// only when this rank is ready for gradient rounds.
    pub fn establish(cfg: &WorldConfig) -> Result<TcpRingTransport> {
        let (rank, world) = (cfg.net.rank, cfg.net.world);
        let tw = TcpWorld::establish(cfg).map_err(|e| {
            anyhow!("establish tcp world (rank {rank} of {world}): {e}")
        })?;
        let t = TcpRingTransport::from_world(tw, cfg.io_timeout)?;
        t.probe()?;
        Ok(t)
    }

    fn from_world(
        w: TcpWorld,
        io_timeout: Duration,
    ) -> Result<TcpRingTransport> {
        if let Some(s) = &w.send {
            s.set_write_timeout(Some(io_timeout))?;
        }
        let reader = match w.recv {
            None => None,
            Some(stream) => {
                // The reader blocks in read() between rounds (no frame
                // is due); liveness while one IS due is enforced by the
                // coordinator's recv_timeout instead.
                stream.set_read_timeout(None)?;
                let shutdown = stream.try_clone()?;
                let (tx, frames) = sync_channel(2);
                let (recycle, recycle_rx) = sync_channel::<Vec<u8>>(2);
                let handle = std::thread::Builder::new()
                    .name(format!("net-recv-{}", w.rank))
                    .spawn(move || reader_loop(stream, tx, recycle_rx))
                    // repo-lint: allow(net-panic) — local thread-spawn
                    // resource exhaustion, not peer-controlled input.
                    .expect("spawn net reader");
                Some(ReaderLink {
                    frames,
                    recycle,
                    shutdown,
                    handle: Some(handle),
                })
            }
        };
        Ok(TcpRingTransport {
            world: w.world,
            rank: w.rank,
            state: Mutex::new(TcpState {
                send: w.send,
                reader,
                frame: Vec::new(),
                payload: Vec::new(),
                round: 0,
                io_timeout,
            }),
        })
    }

    /// This process's world rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Round 0: all-reduce a single 1.0 through the ring. Every rank
    /// must see exactly `world` — a cheap end-to-end check that the
    /// whole ring is connected and counting the same world before the
    /// first gradient round.
    fn probe(&self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let mut bufs = vec![vec![1.0f32]];
        self.all_reduce_sum(&mut bufs)?;
        let sum = bufs[0][0];
        if (sum - self.world as f32).abs() > 0.25 {
            return Err(anyhow!(
                "ring probe: {}",
                NetError::WorldSizeMismatch {
                    ours: self.world as u32,
                    theirs: sum.round() as u32,
                }
            ));
        }
        Ok(())
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TcpState> {
        // A poisoning panic already failed the run; the transport state
        // (streams + scratch) is still structurally sound for cleanup.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl Transport for TcpRingTransport {
    fn world_size(&self) -> usize {
        self.world
    }

    fn local_endpoints(&self) -> usize {
        1
    }

    fn all_reduce_sum(&self, buffers: &mut [Vec<f32>]) -> Result<TransportStats> {
        assert_eq!(buffers.len(), 1, "a tcp rank owns exactly one buffer");
        let mut st = self.lock();
        let round = st.round;
        st.round += 1;
        let n = self.world;
        if n == 1 {
            return Ok(TransportStats { bytes_sent_per_worker: 0, hops: 0 });
        }
        let rank = self.rank;
        let prev = ((rank + n - 1) % n) as u32;
        let buf = &mut buffers[0];
        let len = buf.len();
        // Chunk boundaries: identical to the in-process ring_worker.
        let start = |c: usize| c * len / n;
        let mut sent = 0usize;
        // Phase 1: reduce-scatter (add order identical to ring_worker —
        // own chunk += received chunk, in ring-arrival order).
        for step in 0..n - 1 {
            let send_chunk = (rank + n - step) % n;
            let (s0, s1) = (start(send_chunk), start(send_chunk + 1));
            stage_f32(&mut st.payload, &buf[s0..s1]);
            sent += st
                .send_staged(rank as u32, FrameKind::Data, round)
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} send: {e}")
                })?;
            let recv_chunk = (rank + n - step - 1 + n) % n;
            let (r0, r1) = (start(recv_chunk), start(recv_chunk + 1));
            let data = st
                .recv_expect(FrameKind::Data, prev, round, (r1 - r0) * 4)
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} recv: {e}")
                })?;
            for (dst, src) in buf[r0..r1].iter_mut().zip(data.chunks_exact(4))
            {
                // repo-lint: allow(net-panic) — chunks_exact(4) yields
                // exactly-4-byte slices; recv_expect validated length.
                *dst += f32::from_le_bytes(src.try_into().unwrap());
            }
            st.recycle(data);
        }
        // Phase 2: all-gather.
        for step in 0..n - 1 {
            let send_chunk = (rank + 1 + n - step) % n;
            let (s0, s1) = (start(send_chunk), start(send_chunk + 1));
            stage_f32(&mut st.payload, &buf[s0..s1]);
            sent += st
                .send_staged(rank as u32, FrameKind::Data, round)
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} send: {e}")
                })?;
            let recv_chunk = (rank + n - step) % n;
            let (r0, r1) = (start(recv_chunk), start(recv_chunk + 1));
            let data = st
                .recv_expect(FrameKind::Data, prev, round, (r1 - r0) * 4)
                .map_err(|e| {
                    anyhow!("tcp ring rank {rank} round {round} recv: {e}")
                })?;
            for (dst, src) in buf[r0..r1].iter_mut().zip(data.chunks_exact(4))
            {
                // repo-lint: allow(net-panic) — chunks_exact(4) yields
                // exactly-4-byte slices; recv_expect validated length.
                *dst = f32::from_le_bytes(src.try_into().unwrap());
            }
            st.recycle(data);
        }
        Ok(TransportStats { bytes_sent_per_worker: sent, hops: 2 * (n - 1) })
    }

    /// Ring all-gather of the loss sidecar: on return `out` holds every
    /// rank's `local` values in rank order — the exact fold order the
    /// in-process trainer uses, so loss series match bitwise. Returns
    /// the real wire bytes this rank sent for the sidecar.
    fn all_gather_f64(
        &self,
        local: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<usize> {
        let n = self.world;
        let l = local.len();
        out.clear();
        out.resize(n * l, 0.0);
        out[self.rank * l..(self.rank + 1) * l].copy_from_slice(local);
        if n == 1 {
            return Ok(0);
        }
        let mut st = self.lock();
        let round = st.round;
        st.round += 1;
        let rank = self.rank;
        let prev = ((rank + n - 1) % n) as u32;
        let mut sent = 0usize;
        for step in 0..n - 1 {
            // Relay: first hop sends our own slot, hop s forwards the
            // slot received at hop s-1.
            let send_idx = (rank + n - step) % n;
            stage_f64(&mut st.payload, &out[send_idx * l..(send_idx + 1) * l]);
            sent += st
                .send_staged(rank as u32, FrameKind::Gather, round)
                .map_err(|e| {
                    anyhow!("tcp gather rank {rank} round {round} send: {e}")
                })?;
            let recv_idx = (rank + n - step - 1) % n;
            let data = st
                .recv_expect(FrameKind::Gather, prev, round, l * 8)
                .map_err(|e| {
                    anyhow!("tcp gather rank {rank} round {round} recv: {e}")
                })?;
            for (dst, src) in out[recv_idx * l..(recv_idx + 1) * l]
                .iter_mut()
                .zip(data.chunks_exact(8))
            {
                // repo-lint: allow(net-panic) — chunks_exact(8) yields
                // exactly-8-byte slices; recv_expect validated length.
                *dst = f64::from_le_bytes(src.try_into().unwrap());
            }
            st.recycle(data);
        }
        Ok(sent)
    }
}

impl Drop for TcpRingTransport {
    fn drop(&mut self) {
        let mut st = self.lock();
        if let Some(s) = st.send.take() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(link) = st.reader.take() {
            let ReaderLink { frames, recycle, shutdown, handle } = link;
            // Unblock the reader whether it is parked in read() (stream
            // shutdown -> EOF) or in channel send (receiver dropped).
            let _ = shutdown.shutdown(Shutdown::Both);
            drop(frames);
            drop(recycle);
            if let Some(h) = handle {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::net::world::NetConfig;
    use crate::comm::RingTransport;

    fn free_peers(n: usize) -> Vec<String> {
        crate::comm::net::launch::free_loopback_peers(n).unwrap()
    }

    fn world_cfg(world: usize, rank: usize, peers: Vec<String>) -> WorldConfig {
        let mut cfg = WorldConfig::new(
            NetConfig { world, rank, peers },
            0xBA5E,
            0x1A40,
        );
        cfg.connect_timeout = Duration::from_secs(5);
        cfg.io_timeout = Duration::from_secs(5);
        cfg
    }

    /// Stand up a full loopback world, run `rounds` all-reduces per
    /// rank, and return every rank's final buffer.
    fn run_world(
        world: usize,
        seeds: &[Vec<f32>],
        rounds: usize,
    ) -> Vec<Vec<f32>> {
        let peers = free_peers(world);
        let mut handles = Vec::new();
        for rank in 0..world {
            let cfg = world_cfg(world, rank, peers.clone());
            let mut buf = seeds[rank].clone();
            handles.push(std::thread::spawn(move || {
                let t = TcpRingTransport::establish(&cfg).unwrap();
                for _ in 0..rounds {
                    let mut bufs = vec![std::mem::take(&mut buf)];
                    t.all_reduce_sum(&mut bufs).unwrap();
                    buf = bufs.pop().unwrap();
                }
                buf
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn loopback_world_sums_bitwise_like_inproc() {
        let n = 3;
        let seeds: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..37).map(|i| (r * 100 + i) as f32 * 0.25).collect())
            .collect();
        let mut inproc = seeds.clone();
        RingTransport::new(n).all_reduce_sum(&mut inproc).unwrap();
        let tcp = run_world(n, &seeds, 1);
        for r in 0..n {
            assert_eq!(tcp[r], inproc[r], "rank {r} diverged");
        }
    }

    #[test]
    fn world_one_is_local_noop() {
        let cfg = world_cfg(1, 0, vec!["127.0.0.1:1".into()]);
        let t = TcpRingTransport::establish(&cfg).unwrap();
        assert_eq!(t.world_size(), 1);
        assert_eq!(t.local_endpoints(), 1);
        let mut bufs = vec![vec![2.0f32, 3.0]];
        let stats = t.all_reduce_sum(&mut bufs).unwrap();
        assert_eq!(stats.hops, 0);
        assert_eq!(bufs[0], vec![2.0, 3.0]);
        let mut out = Vec::new();
        t.all_gather_f64(&[1.25, 2.5], &mut out).unwrap();
        assert_eq!(out, vec![1.25, 2.5]);
    }

    #[test]
    fn gather_orders_by_rank() {
        let n = 3;
        let peers = free_peers(n);
        let mut handles = Vec::new();
        for rank in 0..n {
            let cfg = world_cfg(n, rank, peers.clone());
            handles.push(std::thread::spawn(move || {
                let t = TcpRingTransport::establish(&cfg).unwrap();
                let local = [rank as f64 * 10.0, rank as f64 * 10.0 + 1.0];
                let mut out = Vec::new();
                t.all_gather_f64(&local, &mut out).unwrap();
                out
            }));
        }
        for h in handles {
            let got = h.join().unwrap();
            assert_eq!(got, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        }
    }
}
