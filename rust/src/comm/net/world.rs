//! Ring rendezvous: listener binding, neighbor dialing, and the
//! handshake that validates a world before its first gradient round.
//!
//! Every rank binds a listener at its own `peers[rank]` address, dials
//! its downstream neighbor `peers[(rank+1) % world]`, and accepts one
//! connection from its upstream neighbor. Both directions of every link
//! carry a Hello/Welcome exchange of `(world, basis_seed,
//! layout_fingerprint)`, and BOTH endpoints validate — so any
//! misconfigured process is rejected by name at some link of the ring
//! before a single gradient byte moves:
//!
//! * `world-size-mismatch` — the peer was launched with a different
//!   `--world`;
//! * `duplicate-rank` — two processes claim one rank slot (surfaces as a
//!   bind conflict on the shared peer list, or as a Hello carrying our
//!   own rank);
//! * `rank-out-of-range` / `unexpected-rank` — the peer list and rank
//!   assignment disagree;
//! * `basis-seed-mismatch` — the shared-seed low-rank bases would
//!   diverge (different `--seed`);
//! * `layout-mismatch` — the gradient layouts differ (different model).
//!
//! Connections are persistent: the two streams established here are
//! reused for every collective round of the run (no per-round connects,
//! mirroring the zero-respawn discipline of the in-process pool and
//! ring workers).

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::wire;
use super::wire::{
    encode_frame, read_frame, FrameKind, NetError, HEADER_LEN,
};

/// CLI-level world topology: which rank this process is, out of how
/// many, and where every rank listens (`host:port`, index = rank).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    pub world: usize,
    pub rank: usize,
    pub peers: Vec<String>,
}

/// Everything `establish` needs: topology plus the determinism contract
/// (basis seed + layout fingerprint) the handshake enforces.
#[derive(Clone, Debug)]
pub struct WorldConfig {
    pub net: NetConfig,
    pub basis_seed: u64,
    pub layout_fingerprint: u64,
    /// How long to keep retrying the neighbor dial (peers may start in
    /// any order) and to wait for the upstream accept.
    pub connect_timeout: Duration,
    /// Per-frame deadline once the ring is up; also the handshake read
    /// timeout.
    pub io_timeout: Duration,
}

impl WorldConfig {
    pub fn new(net: NetConfig, basis_seed: u64, layout_fingerprint: u64) -> Self {
        WorldConfig {
            net,
            basis_seed,
            layout_fingerprint,
            connect_timeout: Duration::from_secs(20),
            io_timeout: Duration::from_secs(60),
        }
    }
}

/// Hello/Welcome payload: world u32 | basis_seed u64 | layout_fp u64.
pub(crate) fn hello_payload(cfg: &WorldConfig) -> [u8; 20] {
    let mut p = [0u8; 20];
    p[0..4].copy_from_slice(&(cfg.net.world as u32).to_le_bytes());
    p[4..12].copy_from_slice(&cfg.basis_seed.to_le_bytes());
    p[12..20].copy_from_slice(&cfg.layout_fingerprint.to_le_bytes());
    p
}

pub(crate) fn parse_hello(p: &[u8]) -> Result<(u32, u64, u64), NetError> {
    if p.len() != 20 {
        return Err(NetError::Truncated { needed: 20, got: p.len() });
    }
    Ok((
        u32::from_le_bytes(wire::field(p, 0)?),
        u64::from_le_bytes(wire::field(p, 4)?),
        u64::from_le_bytes(wire::field(p, 12)?),
    ))
}

/// Validate a peer's Hello/Welcome against our config. `peer_rank` is
/// the rank the frame header carried; `expected` is the ring neighbor
/// that should be on this link.
fn validate_peer(
    cfg: &WorldConfig,
    peer_rank: u32,
    expected: u32,
    payload: &[u8],
) -> Result<(), NetError> {
    let ours_world = cfg.net.world as u32;
    let (world, seed, fp) = parse_hello(payload)?;
    if world != ours_world {
        return Err(NetError::WorldSizeMismatch { ours: ours_world, theirs: world });
    }
    if peer_rank == cfg.net.rank as u32 {
        return Err(NetError::DuplicateRank { rank: peer_rank, addr: None });
    }
    if peer_rank >= ours_world {
        return Err(NetError::RankOutOfRange { rank: peer_rank, world: ours_world });
    }
    if peer_rank != expected {
        return Err(NetError::UnexpectedRank { expected, got: peer_rank });
    }
    if seed != cfg.basis_seed {
        return Err(NetError::BasisSeedMismatch { ours: cfg.basis_seed, theirs: seed });
    }
    if fp != cfg.layout_fingerprint {
        return Err(NetError::LayoutMismatch {
            ours: cfg.layout_fingerprint,
            theirs: fp,
        });
    }
    Ok(())
}

fn send_frame_blocking(
    stream: &mut TcpStream,
    kind: FrameKind,
    rank: u32,
    payload: &[u8],
) -> Result<(), NetError> {
    use std::io::Write;
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
    encode_frame(&mut buf, kind, rank, 0, payload)?;
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// Accept the upstream neighbor's connection and run the acceptor side
/// of the handshake. On a validation failure the typed error is BOTH
/// returned here and echoed to the dialer as a Reject frame, so each
/// side of a misconfigured link reports the problem by name.
pub fn accept_handshake(
    listener: &TcpListener,
    cfg: &WorldConfig,
) -> Result<TcpStream, NetError> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + cfg.connect_timeout;
    let (mut stream, _addr) = loop {
        match listener.accept() {
            Ok(pair) => break pair,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(NetError::Timeout);
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(NetError::Io(e)),
        }
    };
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(cfg.io_timeout))?;
    let mut payload = Vec::new();
    let hdr = read_frame(&mut stream, &mut payload)?;
    if hdr.kind != FrameKind::Hello {
        return Err(NetError::UnexpectedKind {
            expected: FrameKind::Hello,
            got: hdr.kind,
        });
    }
    let expected_prev =
        ((cfg.net.rank + cfg.net.world - 1) % cfg.net.world) as u32;
    if let Err(err) = validate_peer(cfg, hdr.rank, expected_prev, &payload) {
        // Best-effort: tell the dialer why before hanging up.
        let reason = err.to_string();
        let _ = send_frame_blocking(
            &mut stream,
            FrameKind::Reject,
            cfg.net.rank as u32,
            reason.as_bytes(),
        );
        return Err(err);
    }
    send_frame_blocking(
        &mut stream,
        FrameKind::Welcome,
        cfg.net.rank as u32,
        &hello_payload(cfg),
    )?;
    Ok(stream)
}

/// Dial the downstream neighbor (retrying until it is up) and run the
/// dialer side of the handshake, validating the acceptor symmetrically.
pub fn dial_handshake(cfg: &WorldConfig) -> Result<TcpStream, NetError> {
    let next = (cfg.net.rank + 1) % cfg.net.world;
    let addr = cfg.net.peers[next].clone();
    let deadline = Instant::now() + cfg.connect_timeout;
    let mut stream = loop {
        match TcpStream::connect(&addr) {
            Ok(s) => break s,
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => return Err(NetError::ConnectFailed { addr }),
        }
    };
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(cfg.io_timeout))?;
    send_frame_blocking(
        &mut stream,
        FrameKind::Hello,
        cfg.net.rank as u32,
        &hello_payload(cfg),
    )?;
    let mut payload = Vec::new();
    let hdr = read_frame(&mut stream, &mut payload)?;
    match hdr.kind {
        FrameKind::Welcome => {
            validate_peer(cfg, hdr.rank, next as u32, &payload)?;
            Ok(stream)
        }
        FrameKind::Reject => Err(NetError::HandshakeRejected(
            String::from_utf8_lossy(&payload).into_owned(),
        )),
        other => Err(NetError::UnexpectedKind {
            expected: FrameKind::Welcome,
            got: other,
        }),
    }
}

/// A fully-handshaken ring membership: the persistent send link to the
/// downstream neighbor and receive link from the upstream neighbor.
/// World size 1 holds no sockets (every round is local).
pub struct TcpWorld {
    pub world: usize,
    pub rank: usize,
    pub send: Option<TcpStream>,
    pub recv: Option<TcpStream>,
}

impl TcpWorld {
    /// Bind, dial, accept, and handshake. Returns only once both
    /// neighbor links are up and validated (or a named error).
    pub fn establish(cfg: &WorldConfig) -> Result<TcpWorld, NetError> {
        let NetConfig { world, rank, ref peers } = cfg.net;
        if world == 0 {
            return Err(NetError::Config("world size must be >= 1".into()));
        }
        if rank >= world {
            return Err(NetError::RankOutOfRange {
                rank: rank as u32,
                world: world as u32,
            });
        }
        if world == 1 {
            return Ok(TcpWorld { world, rank, send: None, recv: None });
        }
        if peers.len() != world {
            return Err(NetError::Config(format!(
                "--peers lists {} addresses for a world of {world}",
                peers.len()
            )));
        }
        let listener = TcpListener::bind(&peers[rank]).map_err(|e| {
            if e.kind() == std::io::ErrorKind::AddrInUse {
                // Another process is already listening on our rank's
                // slot: two launches claimed the same rank (or an
                // unrelated daemon holds the port — the address in the
                // message disambiguates).
                NetError::DuplicateRank {
                    rank: rank as u32,
                    addr: Some(peers[rank].clone()),
                }
            } else {
                NetError::Io(e)
            }
        })?;
        // Accept (upstream) and dial (downstream) concurrently — with a
        // 2-rank world the same peer process is on both ends, so doing
        // them sequentially would deadlock.
        let accept_cfg = cfg.clone();
        let acceptor = std::thread::Builder::new()
            .name(format!("net-accept-{rank}"))
            .spawn(move || accept_handshake(&listener, &accept_cfg))
            // repo-lint: allow(net-panic) — local thread-spawn resource
            // exhaustion, not peer-controlled input.
            .expect("spawn net acceptor");
        let dialed = dial_handshake(cfg);
        // repo-lint: allow(net-panic) — accept_handshake returns every
        // peer failure as a typed NetError; a join error means the
        // handshake code itself panicked, which is a local bug.
        let accepted = acceptor.join().expect("net acceptor panicked");
        // A typed validation error from either side beats a generic
        // timeout from the other (the timeout is usually the symptom of
        // the peer having already rejected us).
        match (accepted, dialed) {
            (Ok(recv), Ok(send)) => {
                Ok(TcpWorld { world, rank, send: Some(send), recv: Some(recv) })
            }
            (Err(a), Err(d)) => {
                let a_generic =
                    matches!(a, NetError::Timeout | NetError::Io(_));
                Err(if a_generic { d } else { a })
            }
            (Err(a), Ok(_)) => Err(a),
            (Ok(_), Err(d)) => Err(d),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(world: usize, rank: usize, seed: u64, fp: u64) -> WorldConfig {
        WorldConfig {
            net: NetConfig {
                world,
                rank,
                peers: (0..world).map(|_| "127.0.0.1:0".into()).collect(),
            },
            basis_seed: seed,
            layout_fingerprint: fp,
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(2),
        }
    }

    #[test]
    fn hello_roundtrip() {
        let c = cfg(4, 1, 0xABCD, 0x1234);
        let p = hello_payload(&c);
        assert_eq!(parse_hello(&p).unwrap(), (4, 0xABCD, 0x1234));
        assert_eq!(parse_hello(&p[..10]).unwrap_err().name(), "truncated-frame");
    }

    #[test]
    fn validate_catches_each_field() {
        let ours = cfg(4, 1, 7, 9);
        let ok = hello_payload(&cfg(4, 0, 7, 9));
        assert!(validate_peer(&ours, 0, 0, &ok).is_ok());
        let werr = validate_peer(&ours, 0, 0, &hello_payload(&cfg(5, 0, 7, 9)))
            .unwrap_err();
        assert_eq!(werr.name(), "world-size-mismatch");
        let derr = validate_peer(&ours, 1, 0, &ok).unwrap_err();
        assert_eq!(derr.name(), "duplicate-rank");
        let rerr = validate_peer(&ours, 9, 0, &ok).unwrap_err();
        assert_eq!(rerr.name(), "rank-out-of-range");
        let uerr = validate_peer(&ours, 2, 0, &ok).unwrap_err();
        assert_eq!(uerr.name(), "unexpected-rank");
        let serr = validate_peer(&ours, 0, 0, &hello_payload(&cfg(4, 0, 8, 9)))
            .unwrap_err();
        assert_eq!(serr.name(), "basis-seed-mismatch");
        let ferr = validate_peer(&ours, 0, 0, &hello_payload(&cfg(4, 0, 7, 1)))
            .unwrap_err();
        assert_eq!(ferr.name(), "layout-mismatch");
    }

    #[test]
    fn world_one_needs_no_sockets() {
        let mut c = cfg(1, 0, 0, 0);
        c.net.peers = vec!["127.0.0.1:1".into()]; // never dialed
        let w = TcpWorld::establish(&c).unwrap();
        assert!(w.send.is_none() && w.recv.is_none());
    }
}
