//! `comm::net` — the multi-host TCP backend of the comm subsystem: N
//! independent `grasswalk` processes (same or different hosts) form a
//! deterministic ring and run the dense and low-rank collectives
//! bitwise-identically to the in-process `RingTransport`.
//!
//! Four layers, bottom-up:
//!
//! * [`wire`] — the length-prefixed, CRC-checked frame codec with
//!   version/rank/round headers and the typed [`NetError`] enum (no
//!   panics on malformed peers):
//!
//!   ```text
//!   | magic u32 | ver u16 | kind u8 | tag u8 | rank u32 | round u64 |
//!   | len u32 | payload… | crc32 u32 |
//!   ```
//!
//!   The `tag` byte is kind-specific and CRC-covered: the bucket index
//!   on bucketed reduction Data frames (a divergent peer schedule fails
//!   as `bucket-out-of-order`), the [`crate::comm::WireCodec`] id on
//!   quantized Gather frames (`unknown-wire-codec` /
//!   `quantized-payload-mismatch`), 0 otherwise.
//!
//! * [`world`] — rendezvous and handshake: every rank binds
//!   `peers[rank]`, dials its downstream neighbor, and both endpoints
//!   of every link validate world size, rank uniqueness, shared basis
//!   seed, and grad-layout fingerprint BEFORE the first gradient round.
//!   Connections are persistent — established once, reused every round
//!   (no per-round connects, same zero-respawn discipline as the
//!   worker pool and the in-process ring).
//!
//! * [`transport`] — [`TcpRingTransport`]: the [`crate::comm::Transport`]
//!   impl whose `local_endpoints() == 1`. Chunk boundaries, hop order,
//!   and accumulation order are byte-for-byte the in-process ring's, and
//!   f32 chunks travel as exact little-endian bytes — so a TCP world's
//!   reduced gradients (and therefore its training losses) are bitwise
//!   identical to `--transport inproc`. Two persistent threads per
//!   rank: a reader drains the upstream link so the ring can never
//!   write-write deadlock, and a driver owns the socket schedule so
//!   `reduce_begin`/`gather_bytes_begin` return immediately and the
//!   depth-2 `--overlap` pipeline hides bucket wire time behind
//!   compute. A round-0 probe all-reduces 1.0 to verify the assembled
//!   ring end-to-end.
//!
//! * [`launch`] — `train --spawn-local N`: forks N ranks of this binary
//!   as local subprocesses on auto-assigned loopback ports (tests/CI),
//!   supervising them so one dead rank fails the whole launch.
//!
//! ## Determinism contract
//!
//! Two invariants make `--transport tcp` a drop-in for `inproc`:
//! (1) the handshake pins everything the shared-seed low-rank collective
//! derives locally (basis seed, layout fingerprint, world size), so no
//! basis bytes ever cross the wire; (2) the ring schedule and float
//! encoding are exact, so the reduced mean gradient — and every
//! downstream optimizer step — matches the in-process transport bit for
//! bit (pinned in rust/tests/net_props.rs and the e2e suite).

pub mod launch;
pub mod transport;
pub mod wire;
pub mod world;

pub use transport::TcpRingTransport;
pub use wire::NetError;
pub use world::{NetConfig, WorldConfig};
