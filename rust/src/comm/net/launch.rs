//! `--spawn-local N`: fork a full loopback TCP world of N ranks as
//! subprocesses of the current binary — one command stands up a real
//! multi-process ring for tests, CI, and local experiments.
//!
//! The parent picks N distinct free loopback ports, re-execs itself
//! once per rank with the caller's own training flags plus the
//! generated topology (`--transport tcp --world N --net-rank k --peers
//! ...`), and supervises: the first rank to exit non-zero gets the
//! remaining ranks killed (a half-dead ring would otherwise sit in its
//! io-timeout), and the launcher's own exit reflects the failure.

use std::net::TcpListener;
use std::process::{Child, Command};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// N distinct free loopback ports. The listeners are held open
/// simultaneously (so the OS cannot hand the same port out twice), then
/// dropped just before the ranks spawn and re-bind them. The tiny
/// close-to-rebind window is the standard local-rendezvous tradeoff.
pub fn free_ports(n: usize) -> Result<Vec<u16>> {
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| {
            TcpListener::bind("127.0.0.1:0")
                .context("bind loopback rendezvous port")
        })
        .collect::<Result<_>>()?;
    listeners
        .iter()
        .map(|l| Ok(l.local_addr().context("listener addr")?.port()))
        .collect()
}

/// `free_ports` formatted as a `--peers`-style address list — the one
/// loopback-rendezvous helper shared by the launcher, the equivalence
/// tests, and the benches (so the close-to-rebind caveat above lives in
/// exactly one place).
pub fn free_loopback_peers(n: usize) -> Result<Vec<String>> {
    Ok(free_ports(n)?
        .iter()
        .map(|p| format!("127.0.0.1:{p}"))
        .collect())
}

/// The flags the launcher owns; caller-provided values for these are
/// dropped from the passthrough set so each rank gets exactly one
/// authoritative topology.
const LAUNCH_KEYS: &[&str] =
    &["spawn-local", "transport", "world", "net-rank", "peers"];

/// Strip launcher-owned flags (`--key value` and `--key=value` forms)
/// from a raw argv tail, keeping everything else verbatim.
pub fn strip_launch_args(args: &[String]) -> Vec<String> {
    let mut out = Vec::with_capacity(args.len());
    let mut i = 0;
    while i < args.len() {
        if let Some(body) = args[i].strip_prefix("--") {
            let key = body.split('=').next().unwrap_or(body);
            if LAUNCH_KEYS.contains(&key) {
                // `--key value` consumes the value token too.
                if !body.contains('=')
                    && i + 1 < args.len()
                    && !args[i + 1].starts_with("--")
                {
                    i += 1;
                }
                i += 1;
                continue;
            }
        }
        out.push(args[i].clone());
        i += 1;
    }
    out
}

/// Spawn `world` ranks of `grasswalk train` as local subprocesses and
/// wait for all of them. `raw_args` is the caller's argv tail after the
/// `train` subcommand, forwarded verbatim minus the launcher-owned
/// flags.
pub fn spawn_local(world: usize, raw_args: &[String]) -> Result<()> {
    if world == 0 {
        bail!("--spawn-local needs a world size >= 1");
    }
    let peers = free_loopback_peers(world)?.join(",");
    let exe = std::env::current_exe().context("locate current binary")?;
    let base = strip_launch_args(raw_args);
    eprintln!("[spawn-local] world {world} on {peers}");

    let mut children: Vec<(usize, Option<Child>)> = Vec::with_capacity(world);
    for rank in 0..world {
        let spawned = Command::new(&exe)
            .arg("train")
            .args(&base)
            .args([
                "--transport",
                "tcp",
                "--world",
                &world.to_string(),
                "--net-rank",
                &rank.to_string(),
                "--peers",
                &peers,
            ])
            .spawn()
            .with_context(|| format!("spawn rank {rank}"));
        match spawned {
            Ok(child) => children.push((rank, Some(child))),
            Err(e) => {
                // A missing rank would leave the others waiting out
                // their connect timeout; kill them now.
                for (_, slot) in children.iter_mut() {
                    if let Some(c) = slot.as_mut() {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                }
                return Err(e);
            }
        }
    }

    // Supervise: first non-zero exit kills the remaining ranks.
    let mut failure: Option<(usize, i32)> = None;
    loop {
        let mut running = 0usize;
        for (rank, slot) in children.iter_mut() {
            let Some(child) = slot.as_mut() else { continue };
            match child.try_wait() {
                Ok(Some(status)) => {
                    let code = status.code().unwrap_or(-1);
                    if code != 0 && failure.is_none() {
                        failure = Some((*rank, code));
                    }
                    *slot = None;
                }
                Ok(None) => running += 1,
                Err(e) => {
                    *slot = None;
                    if failure.is_none() {
                        eprintln!("[spawn-local] wait rank {rank}: {e}");
                        failure = Some((*rank, -1));
                    }
                }
            }
        }
        if failure.is_some() {
            for (_, slot) in children.iter_mut() {
                if let Some(c) = slot.as_mut() {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                *slot = None;
            }
            break;
        }
        if running == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(30));
    }
    if let Some((rank, code)) = failure {
        return Err(anyhow!(
            "spawn-local: rank {rank} exited with status {code} \
             (remaining ranks killed)"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn free_ports_are_distinct() {
        let ports = free_ports(4).unwrap();
        for i in 0..ports.len() {
            for j in 0..i {
                assert_ne!(ports[i], ports[j]);
            }
        }
    }

    #[test]
    fn strip_removes_launcher_flags_both_forms() {
        let args = strs(&[
            "--steps",
            "8",
            "--spawn-local",
            "4",
            "--comm",
            "lowrank",
            "--transport=tcp",
            "--world",
            "4",
            "--peers=127.0.0.1:1,127.0.0.1:2",
            "--net-rank",
            "1",
            "--seed",
            "3",
        ]);
        let out = strip_launch_args(&args);
        assert_eq!(
            out,
            strs(&["--steps", "8", "--comm", "lowrank", "--seed", "3"])
        );
    }

    #[test]
    fn strip_keeps_flag_followed_by_flag() {
        // `--spawn-local --steps 8`: spawn-local has no value token.
        let out = strip_launch_args(&strs(&["--spawn-local", "--steps", "8"]));
        assert_eq!(out, strs(&["--steps", "8"]));
    }
}
