//! Length-prefixed, CRC-checked frame codec for the TCP ring transport.
//!
//! ## Wire format (all integers little-endian)
//!
//! ```text
//!  offset  size  field
//!  ------  ----  --------------------------------------------------
//!       0     4  magic        0x47574E31 ("GWN1", sync marker)
//!       4     2  version      protocol version (VERSION)
//!       6     1  kind         FrameKind discriminant
//!       7     1  tag          kind-specific sub-id (0 when unused)
//!       8     4  rank         sender's world rank
//!      12     8  round        sender's collective round counter
//!      20     4  payload_len  payload byte count (<= MAX_PAYLOAD)
//!      24     n  payload      kind-specific bytes
//!    24+n     4  crc32        IEEE CRC32 over bytes [4, 24+n)
//! ```
//!
//! ## The tag byte (v2 codec framing)
//!
//! Byte 7 — reserved-zero in protocol v1 — is a kind-specific sub-id:
//!
//! * `Data` frames of a *bucketed* round carry the bucket index, so a
//!   receiver can detect a peer whose bucket schedule disagrees
//!   ([`NetError::BucketOutOfOrder`]) instead of silently folding the
//!   wrong slice. Unbucketed rounds keep tag 0.
//! * `Gather` frames carrying quantized low-rank factors carry the
//!   [`super::super::codec::WireCodec`] id (0 = f32, 1 = bf16,
//!   2 = int8). A tag outside the codec vocabulary decodes as
//!   [`NetError::UnknownWireCodec`]; a quantized block whose byte count
//!   disagrees with the negotiated layout is
//!   [`NetError::QuantizedPayloadMismatch`]. The f64 loss sidecar
//!   gather keeps tag 0.
//!
//! The tag sits under the CRC like every other header field, and
//! [`encode_frame`] (tag 0) remains byte-compatible with every v1 call
//! site; only [`encode_frame_tagged`] writes a nonzero tag.
//!
//! The CRC covers everything after the magic (header fields AND
//! payload), so a flipped bit anywhere in a frame surfaces as
//! [`NetError::CrcMismatch`] instead of a silently-wrong gradient. A
//! malformed peer can NEVER panic this process: every decode failure is
//! a typed [`NetError`] with a stable [`NetError::name`] the tests and
//! operators match on.
//!
//! EOF discipline: a connection that closes cleanly *between* frames
//! decodes as [`NetError::PeerDisconnected`]; one that dies *inside* a
//! frame decodes as [`NetError::Truncated`].
//!
//! Decode totality (no panic for ANY input byte string) and the
//! encode→decode round-trip identity are model-checked by the bounded
//! Kani harnesses in `rust/verify/wire.rs` (`cargo kani`, nightly
//! verify tier) on top of the unit tests below; header reads go through
//! the bounds-checked [`field`] helper so the property holds by
//! construction, not by buffer-size convention.

use std::fmt;
use std::io::{self, Read};

use crate::util::crc::Crc32;

/// Frame sync marker: "GWN1".
pub const MAGIC: u32 = 0x4757_4E31;
/// Protocol version; bumped on any wire-format change. v2 repurposes
/// the reserved byte at offset 7 as the kind-specific `tag`.
pub const VERSION: u16 = 2;
/// Fixed header size (magic through payload_len).
pub const HEADER_LEN: usize = 24;
/// Trailer size (crc32).
pub const TRAILER_LEN: usize = 4;
/// Hard payload cap — a corrupt length prefix must not OOM the process.
pub const MAX_PAYLOAD: usize = 1 << 30;

/// What a frame carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Dialer → acceptor: world size, basis seed, layout fingerprint.
    Hello = 1,
    /// Acceptor → dialer: handshake accepted (same payload, echoed back
    /// so the dialer validates the acceptor symmetrically).
    Welcome = 2,
    /// Acceptor → dialer: handshake refused; payload = UTF-8 reason.
    Reject = 3,
    /// One ring hop of f32 chunk data (reduce-scatter / all-gather).
    Data = 4,
    /// One ring hop of f64 sidecar data (loss all-gather).
    Gather = 5,
}

impl FrameKind {
    pub fn from_u8(v: u8) -> Option<FrameKind> {
        match v {
            1 => Some(FrameKind::Hello),
            2 => Some(FrameKind::Welcome),
            3 => Some(FrameKind::Reject),
            4 => Some(FrameKind::Data),
            5 => Some(FrameKind::Gather),
            _ => None,
        }
    }
}

/// Decoded frame header (payload travels separately, in a reused buffer).
#[derive(Clone, Copy, Debug)]
pub struct FrameHeader {
    pub kind: FrameKind,
    /// Kind-specific sub-id: bucket index for bucketed `Data` frames,
    /// wire-codec id for quantized `Gather` frames, 0 otherwise.
    pub tag: u8,
    pub rank: u32,
    pub round: u64,
    pub len: usize,
}

/// Every way the net subsystem can fail, as a typed, named error — no
/// panics on malformed peers. `name()` is the stable identifier the
/// failure-mode tests match on.
#[derive(Debug)]
pub enum NetError {
    Io(io::Error),
    /// A read or connect exceeded its deadline.
    Timeout,
    BadMagic(u32),
    VersionMismatch { ours: u16, theirs: u16 },
    UnknownKind(u8),
    /// The stream died mid-frame (or a payload had the wrong size).
    Truncated { needed: usize, got: usize },
    CrcMismatch { expected: u32, got: u32 },
    FrameTooLarge(usize),
    /// Clean close between frames — the peer process went away.
    PeerDisconnected,
    WorldSizeMismatch { ours: u32, theirs: u32 },
    /// Two processes claim the same rank slot (bind conflict or a Hello
    /// carrying our own rank). `addr` names the contested listener
    /// address when the conflict surfaced as a bind failure — without
    /// it an unrelated daemon squatting the port reads as a phantom
    /// duplicate launch.
    DuplicateRank { rank: u32, addr: Option<String> },
    RankOutOfRange { rank: u32, world: u32 },
    /// A frame arrived from the wrong ring neighbor.
    UnexpectedRank { expected: u32, got: u32 },
    BasisSeedMismatch { ours: u64, theirs: u64 },
    LayoutMismatch { ours: u64, theirs: u64 },
    /// Lockstep violation: a frame for a different collective round.
    RoundMismatch { expected: u64, got: u64 },
    UnexpectedKind { expected: FrameKind, got: FrameKind },
    /// A quantized `Gather` frame carried a codec id outside the wire
    /// vocabulary (f32/bf16/int8).
    UnknownWireCodec(u8),
    /// A quantized factor block's byte count disagrees with what the
    /// negotiated layout + codec imply (truncated scales, wrong rank,
    /// or a peer running a different `--wire`).
    QuantizedPayloadMismatch { expected: usize, got: usize },
    /// A bucketed `Data` frame arrived for the wrong bucket index — the
    /// peer's bucket schedule disagrees with ours.
    BucketOutOfOrder { expected: u8, got: u8 },
    /// The remote acceptor refused our handshake; reason echoed back.
    HandshakeRejected(String),
    ConnectFailed { addr: String },
    Config(String),
}

impl NetError {
    /// Stable kebab-case identifier for each failure class.
    pub fn name(&self) -> &'static str {
        match self {
            NetError::Io(_) => "io-error",
            NetError::Timeout => "peer-timeout",
            NetError::BadMagic(_) => "bad-magic",
            NetError::VersionMismatch { .. } => "version-mismatch",
            NetError::UnknownKind(_) => "unknown-frame-kind",
            NetError::Truncated { .. } => "truncated-frame",
            NetError::CrcMismatch { .. } => "corrupt-frame",
            NetError::FrameTooLarge(_) => "frame-too-large",
            NetError::PeerDisconnected => "peer-disconnected",
            NetError::WorldSizeMismatch { .. } => "world-size-mismatch",
            NetError::DuplicateRank { .. } => "duplicate-rank",
            NetError::RankOutOfRange { .. } => "rank-out-of-range",
            NetError::UnexpectedRank { .. } => "unexpected-rank",
            NetError::BasisSeedMismatch { .. } => "basis-seed-mismatch",
            NetError::LayoutMismatch { .. } => "layout-mismatch",
            NetError::RoundMismatch { .. } => "round-mismatch",
            NetError::UnexpectedKind { .. } => "unexpected-frame-kind",
            NetError::UnknownWireCodec(_) => "unknown-wire-codec",
            NetError::QuantizedPayloadMismatch { .. } => {
                "quantized-payload-mismatch"
            }
            NetError::BucketOutOfOrder { .. } => "bucket-out-of-order",
            NetError::HandshakeRejected(_) => "handshake-rejected",
            NetError::ConnectFailed { .. } => "connect-failed",
            NetError::Config(_) => "net-config",
        }
    }
}

impl fmt::Display for NetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: ", self.name())?;
        match self {
            NetError::Io(e) => write!(f, "{e}"),
            NetError::Timeout => write!(f, "peer did not respond in time"),
            NetError::BadMagic(m) => {
                write!(f, "expected {MAGIC:#010x}, got {m:#010x}")
            }
            NetError::VersionMismatch { ours, theirs } => {
                write!(f, "we speak v{ours}, peer sent v{theirs}")
            }
            NetError::UnknownKind(k) => write!(f, "kind byte {k}"),
            NetError::Truncated { needed, got } => {
                write!(f, "needed {needed} bytes, got {got}")
            }
            NetError::CrcMismatch { expected, got } => {
                write!(f, "crc {expected:#010x} expected, frame carried {got:#010x}")
            }
            NetError::FrameTooLarge(n) => {
                write!(f, "payload of {n} bytes exceeds {MAX_PAYLOAD}")
            }
            NetError::PeerDisconnected => {
                write!(f, "connection closed by peer")
            }
            NetError::WorldSizeMismatch { ours, theirs } => {
                write!(f, "our world is {ours}, peer's is {theirs}")
            }
            NetError::DuplicateRank { rank, addr } => {
                write!(f, "another process already claims rank {rank}")?;
                if let Some(a) = addr {
                    write!(f, " (listener bind {a}: address in use)")?;
                }
                Ok(())
            }
            NetError::RankOutOfRange { rank, world } => {
                write!(f, "rank {rank} outside world of {world}")
            }
            NetError::UnexpectedRank { expected, got } => {
                write!(f, "expected ring neighbor {expected}, got rank {got}")
            }
            NetError::BasisSeedMismatch { ours, theirs } => {
                write!(f, "our shared basis seed {ours:#x}, peer's {theirs:#x}")
            }
            NetError::LayoutMismatch { ours, theirs } => {
                write!(
                    f,
                    "our grad layout fingerprint {ours:#x}, peer's {theirs:#x}"
                )
            }
            NetError::RoundMismatch { expected, got } => {
                write!(f, "expected round {expected}, frame is for {got}")
            }
            NetError::UnexpectedKind { expected, got } => {
                write!(f, "expected {expected:?}, got {got:?}")
            }
            NetError::UnknownWireCodec(t) => {
                write!(f, "codec tag byte {t} is not f32/bf16/int8")
            }
            NetError::QuantizedPayloadMismatch { expected, got } => {
                write!(
                    f,
                    "quantized block of {got} bytes, layout implies {expected}"
                )
            }
            NetError::BucketOutOfOrder { expected, got } => {
                write!(f, "expected bucket {expected}, frame is for {got}")
            }
            NetError::HandshakeRejected(reason) => {
                write!(f, "peer refused: {reason}")
            }
            NetError::ConnectFailed { addr } => {
                write!(f, "no peer listening at {addr} within the deadline")
            }
            NetError::Config(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for NetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NetError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for NetError {
    fn from(e: io::Error) -> NetError {
        match e.kind() {
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                NetError::Timeout
            }
            _ => NetError::Io(e),
        }
    }
}

/// Encode one frame into `out` (cleared and reused — steady-state rounds
/// reuse the buffer's capacity). Returns the total frame size in bytes,
/// which is exactly what goes on the wire. A payload beyond
/// [`MAX_PAYLOAD`] is rejected HERE, sender-side — the u32 length
/// prefix must never wrap and desync the stream (a 7B-parameter model's
/// 14 GB chunk would otherwise misparse at the receiver as cascading
/// bad-magic errors).
// hot-path
pub fn encode_frame(
    out: &mut Vec<u8>,
    kind: FrameKind,
    rank: u32,
    round: u64,
    payload: &[u8],
) -> Result<usize, NetError> {
    encode_frame_tagged(out, kind, 0, rank, round, payload)
}

/// [`encode_frame`] with an explicit tag byte — bucket index for
/// bucketed `Data` frames, wire-codec id for quantized `Gather` frames.
// hot-path
pub fn encode_frame_tagged(
    out: &mut Vec<u8>,
    kind: FrameKind,
    tag: u8,
    rank: u32,
    round: u64,
    payload: &[u8],
) -> Result<usize, NetError> {
    if payload.len() > MAX_PAYLOAD {
        return Err(NetError::FrameTooLarge(payload.len()));
    }
    out.clear();
    out.reserve(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind as u8);
    out.push(tag);
    out.extend_from_slice(&rank.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let mut crc = Crc32::new();
    crc.update(&out[4..]);
    out.extend_from_slice(&crc.finish().to_le_bytes());
    Ok(out.len())
}

/// Copy `N` little-endian bytes starting at `off` out of `src` as a
/// fixed-size array, or a typed [`NetError::Truncated`] when the range
/// is out of bounds. This is the panic-free-by-construction replacement
/// for the old `buf[a..b].try_into().unwrap()` header slicing: the
/// compiler can no longer produce an index-out-of-bounds panic from a
/// decode path, whatever the buffer size — a property the
/// `rust/verify/wire.rs` Kani totality harness pins for every input
/// byte string, and the repo lint enforces by forbidding `.unwrap()` in
/// `comm/net/` entirely.
#[inline]
pub(crate) fn field<const N: usize>(
    src: &[u8],
    off: usize,
) -> Result<[u8; N], NetError> {
    match off.checked_add(N) {
        Some(end) if end <= src.len() => {
            let mut out = [0u8; N];
            out.copy_from_slice(&src[off..end]);
            Ok(out)
        }
        _ => Err(NetError::Truncated {
            needed: off.saturating_add(N),
            got: src.len(),
        }),
    }
}

/// Fill `buf` from the reader. `frame_start` selects the EOF semantics:
/// a clean close before the first byte is `PeerDisconnected`; any later
/// EOF is `Truncated`.
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    frame_start: bool,
) -> Result<(), NetError> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if frame_start && got == 0 {
                    NetError::PeerDisconnected
                } else {
                    NetError::Truncated { needed: buf.len(), got }
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(NetError::from(e)),
        }
    }
    Ok(())
}

/// Read and validate one frame. The payload lands in `payload` (cleared
/// and reused across calls — zero steady-state allocations once its
/// capacity covers the largest chunk).
// hot-path
pub fn read_frame(
    r: &mut impl Read,
    payload: &mut Vec<u8>,
) -> Result<FrameHeader, NetError> {
    let mut head = [0u8; HEADER_LEN];
    read_full(r, &mut head, true)?;
    let magic = u32::from_le_bytes(field(&head, 0)?);
    if magic != MAGIC {
        return Err(NetError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(field(&head, 4)?);
    if version != VERSION {
        return Err(NetError::VersionMismatch { ours: VERSION, theirs: version });
    }
    let kind =
        FrameKind::from_u8(head[6]).ok_or(NetError::UnknownKind(head[6]))?;
    let tag = head[7];
    let rank = u32::from_le_bytes(field(&head, 8)?);
    let round = u64::from_le_bytes(field(&head, 12)?);
    let len = u32::from_le_bytes(field(&head, 20)?) as usize;
    if len > MAX_PAYLOAD {
        return Err(NetError::FrameTooLarge(len));
    }
    payload.resize(len, 0);
    read_full(r, payload, false)?;
    let mut crc_bytes = [0u8; TRAILER_LEN];
    read_full(r, &mut crc_bytes, false)?;
    let got = u32::from_le_bytes(crc_bytes);
    let mut crc = Crc32::new();
    crc.update(&head[4..]);
    crc.update(payload);
    let expected = crc.finish();
    if got != expected {
        return Err(NetError::CrcMismatch { expected, got });
    }
    Ok(FrameHeader { kind, tag, rank, round, len })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(kind: FrameKind, rank: u32, round: u64, payload: &[u8]) {
        let mut frame = Vec::new();
        let total = encode_frame(&mut frame, kind, rank, round, payload).unwrap();
        assert_eq!(total, HEADER_LEN + payload.len() + TRAILER_LEN);
        let mut cursor = &frame[..];
        let mut out = Vec::new();
        let hdr = read_frame(&mut cursor, &mut out).unwrap();
        assert_eq!(hdr.kind, kind);
        assert_eq!(hdr.rank, rank);
        assert_eq!(hdr.round, round);
        assert_eq!(hdr.len, payload.len());
        assert_eq!(out, payload);
        assert!(cursor.is_empty(), "frame must consume exactly its bytes");
    }

    #[test]
    fn field_reads_are_bounds_checked() {
        let buf = [1u8, 2, 3, 4, 5];
        assert_eq!(field::<4>(&buf, 0).unwrap(), [1, 2, 3, 4]);
        assert_eq!(field::<2>(&buf, 3).unwrap(), [4, 5]);
        let err = field::<4>(&buf, 2).unwrap_err();
        assert_eq!(err.name(), "truncated-frame");
        // Offset arithmetic can never wrap into a bogus in-bounds read.
        let err = field::<8>(&buf, usize::MAX - 2).unwrap_err();
        assert_eq!(err.name(), "truncated-frame");
    }

    #[test]
    fn frames_roundtrip() {
        roundtrip(FrameKind::Hello, 0, 0, &[]);
        roundtrip(FrameKind::Data, 3, 17, &[1, 2, 3, 4, 5]);
        roundtrip(FrameKind::Gather, 7, u64::MAX, &[0u8; 128]);
    }

    #[test]
    fn tagged_frames_roundtrip_and_untagged_is_tag_zero() {
        for tag in [0u8, 1, 2, 7, 255] {
            let mut frame = Vec::new();
            encode_frame_tagged(
                &mut frame,
                FrameKind::Data,
                tag,
                3,
                9,
                &[4u8; 12],
            )
            .unwrap();
            let mut out = Vec::new();
            let hdr = read_frame(&mut &frame[..], &mut out).unwrap();
            assert_eq!(hdr.tag, tag);
            assert_eq!(hdr.rank, 3);
            assert_eq!(hdr.round, 9);
        }
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Gather, 1, 2, &[8u8; 8]).unwrap();
        let mut out = Vec::new();
        assert_eq!(read_frame(&mut &frame[..], &mut out).unwrap().tag, 0);
    }

    #[test]
    fn tag_byte_sits_under_the_crc() {
        let mut frame = Vec::new();
        encode_frame_tagged(&mut frame, FrameKind::Data, 5, 0, 0, &[1u8; 4])
            .unwrap();
        frame[7] ^= 0x02; // corrupt the tag in flight
        let mut out = Vec::new();
        let err = read_frame(&mut &frame[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "corrupt-frame");
    }

    #[test]
    fn codec_and_bucket_errors_have_stable_names() {
        assert_eq!(
            NetError::UnknownWireCodec(9).name(),
            "unknown-wire-codec"
        );
        assert_eq!(
            NetError::QuantizedPayloadMismatch { expected: 64, got: 60 }
                .name(),
            "quantized-payload-mismatch"
        );
        assert_eq!(
            NetError::BucketOutOfOrder { expected: 1, got: 2 }.name(),
            "bucket-out-of-order"
        );
        // Display stays prefixed by the stable name, like every NetError.
        let msg = NetError::BucketOutOfOrder { expected: 1, got: 2 }
            .to_string();
        assert!(msg.starts_with("bucket-out-of-order: "), "{msg}");
    }

    #[test]
    fn payload_buffer_is_reused() {
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Data, 0, 1, &[9u8; 64]).unwrap();
        let mut out = Vec::with_capacity(64);
        let ptr_before = out.as_ptr();
        let mut cursor = &frame[..];
        read_frame(&mut cursor, &mut out).unwrap();
        assert_eq!(out.as_ptr(), ptr_before, "no realloc within capacity");
    }

    #[test]
    fn corrupt_payload_is_crc_mismatch() {
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Data, 1, 2, &[7u8; 32]).unwrap();
        let mid = HEADER_LEN + 5;
        frame[mid] ^= 0xFF;
        let mut out = Vec::new();
        let err = read_frame(&mut &frame[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "corrupt-frame");
    }

    #[test]
    fn corrupt_header_field_is_caught_by_crc() {
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Data, 1, 2, &[7u8; 8]).unwrap();
        frame[12] ^= 0x01; // flip a round bit
        let mut out = Vec::new();
        let err = read_frame(&mut &frame[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "corrupt-frame");
    }

    #[test]
    fn truncated_frame_names_itself() {
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Data, 1, 2, &[7u8; 32]).unwrap();
        let mut out = Vec::new();
        // Cut inside the payload.
        let err =
            read_frame(&mut &frame[..HEADER_LEN + 10], &mut out).unwrap_err();
        assert_eq!(err.name(), "truncated-frame");
        // Cut inside the header.
        let err = read_frame(&mut &frame[..7], &mut out).unwrap_err();
        assert_eq!(err.name(), "truncated-frame");
    }

    #[test]
    fn clean_eof_is_peer_disconnected() {
        let empty: &[u8] = &[];
        let mut out = Vec::new();
        let err = read_frame(&mut &empty[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "peer-disconnected");
    }

    #[test]
    fn bad_magic_and_version_named() {
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Hello, 0, 0, &[]).unwrap();
        let mut garbled = frame.clone();
        garbled[0] = 0x00;
        let mut out = Vec::new();
        let err = read_frame(&mut &garbled[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "bad-magic");
        // Version check fires before the CRC (a future-version peer is a
        // version problem, not corruption).
        let mut newer = frame;
        newer[4] = 0xFE;
        let err = read_frame(&mut &newer[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "version-mismatch");
    }

    #[test]
    fn oversize_length_prefix_rejected_without_allocating() {
        let mut frame = Vec::new();
        encode_frame(&mut frame, FrameKind::Data, 0, 0, &[1u8; 4]).unwrap();
        frame[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut out = Vec::new();
        let err = read_frame(&mut &frame[..], &mut out).unwrap_err();
        assert_eq!(err.name(), "frame-too-large");
        assert!(out.capacity() < 1024, "must not size to the bogus prefix");
    }
}
