//! The transport layer of the `comm` subsystem: how raw f32 payloads move
//! between data-parallel workers.
//!
//! [`Transport`] abstracts one synchronous collective round over N worker
//! endpoints so that backends can be swapped without touching the
//! [`super::Collective`] layer above. Two backends exist:
//!
//! * the in-process [`RingTransport`] here (stands in for NCCL) — every
//!   rank's buffer lives in this process (`local_endpoints() == N`);
//! * the multi-host [`super::net::TcpRingTransport`] — this process IS
//!   one rank of the world and owns exactly one buffer
//!   (`local_endpoints() == 1`); the other ranks are peer processes
//!   reached over persistent TCP links.
//!
//! Both run the *same* ring schedule with the same chunk boundaries and
//! accumulation order, so reduced results are bitwise identical across
//! backends (pinned in rust/tests/net_props.rs).
//!
//! ## Persistent ring workers
//!
//! The legacy `coordinator::allreduce::Ring` spawned N scoped threads and
//! N channels on *every* `all_reduce_sum` call — one full thread
//! fork/join per training step. `RingTransport` creates the N worker
//! threads and the N neighbor links once, at construction, and reuses
//! them for every round: a round is one bounded-channel handoff of each
//! worker's buffer in and out. Steady-state collective rounds therefore
//! perform zero thread spawns — and, since the per-link chunk buffers
//! ping-pong around the ring (each hop reuses the vec received from the
//! upstream neighbor as its next send buffer), zero heap allocations
//! (hard-asserted in benches/coordinator.rs; the old code paid 2·(N−1)
//! `to_vec` allocations per worker per round).
//!
//! The wire schedule is the classic bandwidth-optimal two-phase ring —
//! reduce-scatter (N−1 hops) then all-gather (N−1 hops), ~2·(N−1)/N of
//! the buffer sent per worker — with chunk boundaries and add order kept
//! *identical* to the legacy implementation, so results are bitwise equal
//! (pinned in rust/tests/comm_props.rs).

use std::collections::VecDeque;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use anyhow::{bail, Result};

/// Per-round transport accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Bytes sent by the busiest worker this round. For the in-process
    /// ring this is the f32 payload × 4; for socket backends it is the
    /// real wire byte count including frame headers.
    pub bytes_sent_per_worker: usize,
    /// Point-to-point hops per worker (2·(N−1) for the ring schedule).
    pub hops: usize,
}

/// One synchronous all-reduce round over N worker endpoints.
///
/// `Send` (not `Sync`): a transport is owned by one coordinator — the
/// trainer — and driven from its thread; worker-side parallelism lives
/// behind the implementation.
///
/// Rounds are fallible: a socket backend surfaces peer failures
/// (disconnects, corrupt frames, timeouts) as typed errors instead of
/// panicking; the in-process backend only fails on programmer error.
pub trait Transport: Send {
    /// Global world size N: the number of rank buffers one collective
    /// round reduces over, across every participating process.
    fn world_size(&self) -> usize;

    /// How many of the world's rank buffers live in THIS process — the
    /// length `all_reduce_sum` expects of its `buffers` slice. The
    /// in-process ring holds all of them; one TCP rank holds exactly 1.
    fn local_endpoints(&self) -> usize {
        self.world_size()
    }

    /// All-reduce (sum) the per-endpoint vectors in place. Every vector
    /// must have the same length; on return every vector holds the
    /// world-wide sum.
    fn all_reduce_sum(&self, buffers: &mut [Vec<f32>]) -> Result<TransportStats>;

    /// All-gather scalar sidecar data (per-microbatch losses): `local`
    /// holds this process's endpoints' values in endpoint order; on
    /// return `out` holds every rank's values in rank order. For the
    /// in-process backend the local endpoints ARE the world, so this is
    /// the identity; socket backends circulate the values around the
    /// ring. The rank-major ordering is what keeps the trainer's loss
    /// fold bitwise identical across backends. Returns the wire bytes
    /// this rank sent (0 in-process), so the trainer's `comm/bytes`
    /// series can account for the sidecar alongside the gradient round.
    fn all_gather_f64(
        &self,
        local: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<usize> {
        out.clear();
        out.extend_from_slice(local);
        Ok(0)
    }

    /// Whether this backend can run [`Transport::reduce_begin`] /
    /// [`Transport::reduce_finish`] rounds concurrently with coordinator
    /// compute. Backends that return `false` are still correct — the
    /// bucketed collective simply degrades to serial per-bucket rounds,
    /// which are bitwise identical anyway.
    fn supports_overlap(&self) -> bool {
        false
    }

    /// The world rank of this process's endpoint 0 — the slot its
    /// buffers occupy in rank-ordered gathers. 0 for the in-process
    /// ring (whose endpoints ARE ranks 0..N); the net rank for a TCP
    /// process.
    fn rank_offset(&self) -> usize {
        0
    }

    /// Begin an asynchronous all-reduce round: ownership of the
    /// per-endpoint buffers moves into the transport, the wire work
    /// proceeds in the background (ring worker threads in-process, the
    /// net driver thread over TCP), and the coordinator keeps computing.
    /// Rounds complete strictly FIFO via [`Transport::reduce_finish`].
    /// `tag` is the bucket index of a bucketed round (0 when
    /// unbucketed), stamped on every frame a socket backend sends so a
    /// peer with a divergent bucket schedule fails by name instead of
    /// folding the wrong slice. At most two rounds may be in flight
    /// (the depth-2 bucket pipeline) — that bound is what lets every
    /// backend run on its existing bounded channels without growing
    /// them.
    fn reduce_begin(&self, _buffers: Vec<Vec<f32>>, _tag: u8) -> Result<()> {
        bail!("transport backend does not support overlapped reduction")
    }

    /// Finish the OLDEST in-flight [`Transport::reduce_begin`] round,
    /// returning the same buffer allocations (now holding the
    /// world-wide sums) so steady-state rounds stay 0-alloc.
    fn reduce_finish(&self) -> Result<(Vec<Vec<f32>>, TransportStats)> {
        bail!("transport backend does not support overlapped reduction")
    }

    /// All-gather opaque byte blocks (quantized low-rank factors):
    /// `blocks` has exactly `world_size()` entries in rank order; the
    /// caller fills the local endpoints' slots (starting at
    /// [`Transport::rank_offset`]) and the transport fills the rest,
    /// reusing each slot's allocation once its capacity covers the
    /// block. `tag` is the wire-codec id stamped into each frame's tag
    /// byte so a receiver can reject a mismatched `--wire` peer by
    /// name. Returns the wire bytes this rank sent (the in-process
    /// default is the identity and sends nothing). Byte identity — not
    /// summation — is the point: every rank dequantizes and folds the
    /// same blocks in the same rank order, which is what keeps
    /// quantized rounds bitwise identical across transports.
    fn all_gather_bytes(
        &self,
        _blocks: &mut Vec<Vec<u8>>,
        _tag: u8,
    ) -> Result<usize> {
        Ok(0)
    }

    /// Asynchronous [`Transport::all_gather_bytes`]: begin ships the
    /// local blocks, finish returns the world's blocks FIFO (same
    /// depth-2 bound as `reduce_begin`).
    fn gather_bytes_begin(
        &self,
        _blocks: Vec<Vec<u8>>,
        _tag: u8,
    ) -> Result<()> {
        bail!("transport backend does not support overlapped gather")
    }

    fn gather_bytes_finish(&self) -> Result<(Vec<Vec<u8>>, usize)> {
        bail!("transport backend does not support overlapped gather")
    }
}

/// Persistent in-process ring: N worker threads + N neighbor links
/// created once, reused for every collective round.
pub struct RingTransport {
    n: usize,
    /// Per-worker round dispatch (buffer ownership moves in).
    jobs: Vec<SyncSender<Vec<f32>>>,
    /// Per-worker round completion (buffer + bytes-sent move out).
    done: Vec<Receiver<(Vec<f32>, usize)>>,
    handles: Vec<JoinHandle<()>>,
    /// FIFO of in-flight `reduce_begin` rounds: the emptied outer
    /// shells awaiting refill at `reduce_finish` (for n == 1 the shell
    /// still holds its buffers — the round is a local no-op). The
    /// deque's capacity is reused round over round, so the overlap path
    /// adds zero steady-state allocations.
    inflight: Mutex<VecDeque<Vec<Vec<f32>>>>,
    /// FIFO of in-flight byte-gather rounds (identity in-process).
    gathers: Mutex<VecDeque<(Vec<Vec<u8>>, usize)>>,
}

impl RingTransport {
    pub fn new(n: usize) -> RingTransport {
        assert!(n >= 1);
        if n == 1 {
            // Degenerate world: no threads, every round is a no-op.
            return RingTransport {
                n,
                jobs: Vec::new(),
                done: Vec::new(),
                handles: Vec::new(),
                inflight: Mutex::new(VecDeque::new()),
                gathers: Mutex::new(VecDeque::new()),
            };
        }
        // Neighbor links: link_tx[i] feeds worker (i+1) % n.
        let mut link_tx: Vec<Option<SyncSender<Vec<f32>>>> =
            (0..n).map(|_| None).collect();
        let mut link_rx: Vec<Option<Receiver<Vec<f32>>>> =
            (0..n).map(|_| None).collect();
        for i in 0..n {
            let (tx, rx) = sync_channel::<Vec<f32>>(1);
            link_tx[i] = Some(tx);
            link_rx[(i + 1) % n] = Some(rx);
        }
        let mut jobs = Vec::with_capacity(n);
        let mut done = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for rank in 0..n {
            let (job_tx, job_rx) = sync_channel::<Vec<f32>>(1);
            let (done_tx, done_rx) = sync_channel::<(Vec<f32>, usize)>(1);
            let tx = link_tx[rank].take().unwrap();
            let rx = link_rx[rank].take().unwrap();
            let handle = std::thread::Builder::new()
                .name(format!("comm-ring-{rank}"))
                .spawn(move || ring_worker(rank, n, job_rx, done_tx, tx, rx))
                .expect("spawn comm ring worker");
            jobs.push(job_tx);
            done.push(done_rx);
            handles.push(handle);
        }
        RingTransport {
            n,
            jobs,
            done,
            handles,
            inflight: Mutex::new(VecDeque::new()),
            gathers: Mutex::new(VecDeque::new()),
        }
    }

    fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
        m.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl Transport for RingTransport {
    fn world_size(&self) -> usize {
        self.n
    }

    fn all_reduce_sum(&self, buffers: &mut [Vec<f32>]) -> Result<TransportStats> {
        let n = self.n;
        assert_eq!(buffers.len(), n, "one buffer per ring worker");
        if n == 1 {
            return Ok(TransportStats { bytes_sent_per_worker: 0, hops: 0 });
        }
        let len = buffers[0].len();
        assert!(buffers.iter().all(|b| b.len() == len));
        // Dispatch every buffer, then collect every result. Workers run
        // in lockstep through their links; the coordinator never starts
        // round k+1 before every worker reported round k, so links carry
        // exactly one round's chunks at a time.
        for (i, buf) in buffers.iter_mut().enumerate() {
            self.jobs[i]
                .send(std::mem::take(buf))
                .expect("comm ring worker gone");
        }
        let mut bytes = 0usize;
        for (i, buf) in buffers.iter_mut().enumerate() {
            let (out, sent) =
                self.done[i].recv().expect("comm ring worker gone");
            *buf = out;
            bytes = bytes.max(sent);
        }
        Ok(TransportStats { bytes_sent_per_worker: bytes, hops: 2 * (n - 1) })
    }

    fn supports_overlap(&self) -> bool {
        self.n > 1
    }

    fn reduce_begin(&self, mut buffers: Vec<Vec<f32>>, _tag: u8) -> Result<()> {
        let n = self.n;
        if buffers.len() != n {
            bail!("reduce_begin: {} buffers for {n} workers", buffers.len());
        }
        if n > 1 {
            let len = buffers[0].len();
            if buffers.iter().any(|b| b.len() != len) {
                bail!("reduce_begin: ragged buffer lengths");
            }
            // Hand every buffer to its ring worker; the emptied outer
            // shell queues for the matching `reduce_finish`. The job
            // channels' capacity 1 is enough for the depth-2 pipeline:
            // by the time a third `reduce_begin` runs, `reduce_finish`
            // has drained the first round, which means every worker has
            // delivered its result and is already dequeuing the second
            // round's job.
            for (i, buf) in buffers.iter_mut().enumerate() {
                if self.jobs[i].send(std::mem::take(buf)).is_err() {
                    bail!("comm ring worker {i} gone");
                }
            }
        }
        Self::lock(&self.inflight).push_back(buffers);
        Ok(())
    }

    fn reduce_finish(&self) -> Result<(Vec<Vec<f32>>, TransportStats)> {
        let Some(mut shell) = Self::lock(&self.inflight).pop_front() else {
            bail!("reduce_finish without a matching reduce_begin");
        };
        let n = self.n;
        if n == 1 {
            return Ok((
                shell,
                TransportStats { bytes_sent_per_worker: 0, hops: 0 },
            ));
        }
        let mut bytes = 0usize;
        for (i, slot) in shell.iter_mut().enumerate() {
            let Ok((out, sent)) = self.done[i].recv() else {
                bail!("comm ring worker {i} gone");
            };
            *slot = out;
            bytes = bytes.max(sent);
        }
        Ok((
            shell,
            TransportStats { bytes_sent_per_worker: bytes, hops: 2 * (n - 1) },
        ))
    }

    fn all_gather_bytes(
        &self,
        blocks: &mut Vec<Vec<u8>>,
        _tag: u8,
    ) -> Result<usize> {
        // In-process the local endpoints ARE the world, so the gather is
        // the identity; report the payload bytes the busiest rank of a
        // real ring relay would send ((n−1) hops of its largest block),
        // mirroring how `all_reduce_sum` accounts payload in-process.
        if blocks.len() != self.n {
            bail!(
                "all_gather_bytes: {} blocks for {} endpoints",
                blocks.len(),
                self.n
            );
        }
        Ok(self.simulated_gather_bytes(blocks))
    }

    fn gather_bytes_begin(
        &self,
        blocks: Vec<Vec<u8>>,
        _tag: u8,
    ) -> Result<()> {
        if blocks.len() != self.n {
            bail!(
                "gather_bytes_begin: {} blocks for {} endpoints",
                blocks.len(),
                self.n
            );
        }
        let bytes = self.simulated_gather_bytes(&blocks);
        Self::lock(&self.gathers).push_back((blocks, bytes));
        Ok(())
    }

    fn gather_bytes_finish(&self) -> Result<(Vec<Vec<u8>>, usize)> {
        match Self::lock(&self.gathers).pop_front() {
            Some(round) => Ok(round),
            None => {
                bail!("gather_bytes_finish without a matching begin")
            }
        }
    }
}

impl RingTransport {
    /// Payload bytes the busiest rank of an (n−1)-hop ring relay of
    /// these blocks would send — the in-process stand-in for real wire
    /// traffic, zero for the degenerate single-worker world.
    fn simulated_gather_bytes(&self, blocks: &[Vec<u8>]) -> usize {
        if self.n == 1 {
            return 0;
        }
        let largest = blocks.iter().map(Vec::len).max().unwrap_or(0);
        (self.n - 1) * largest
    }
}

impl Drop for RingTransport {
    fn drop(&mut self) {
        // Closing the job channels makes every worker's recv fail -> exit.
        self.jobs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One persistent ring worker: blocks for a round's buffer, runs the
/// two-phase schedule through its neighbor links, hands the buffer back.
/// Chunk math and accumulation order mirror the legacy
/// `coordinator::allreduce::Ring` loop for bitwise equality.
///
/// Chunk buffers ping-pong: the worker holds ONE spare vec, fills it
/// with the outgoing chunk, sends it, and adopts the vec received from
/// its upstream neighbor as the next spare — so after the first round
/// the N circulating vecs are reused forever and the steady-state round
/// performs zero heap allocations.
fn ring_worker(
    rank: usize,
    n: usize,
    job_rx: Receiver<Vec<f32>>,
    done_tx: SyncSender<(Vec<f32>, usize)>,
    link_tx: SyncSender<Vec<f32>>,
    link_rx: Receiver<Vec<f32>>,
) {
    let mut spare: Vec<f32> = Vec::new();
    while let Ok(mut buf) = job_rx.recv() {
        let len = buf.len();
        // Chunk boundaries (chunk c: [start(c), start(c+1))).
        let start = |c: usize| c * len / n;
        let mut sent = 0usize;
        // Phase 1: reduce-scatter.
        for step in 0..n - 1 {
            let send_chunk = (rank + n - step) % n;
            let (s0, s1) = (start(send_chunk), start(send_chunk + 1));
            spare.clear();
            spare.extend_from_slice(&buf[s0..s1]);
            if link_tx.send(std::mem::take(&mut spare)).is_err() {
                return;
            }
            sent += (s1 - s0) * 4;
            let recv_chunk = (rank + n - step - 1 + n) % n;
            let Ok(data) = link_rx.recv() else { return };
            let (r0, r1) = (start(recv_chunk), start(recv_chunk + 1));
            for (dst, src) in buf[r0..r1].iter_mut().zip(&data) {
                *dst += *src;
            }
            spare = data; // ping-pong: reuse the neighbor's vec next hop
        }
        // Phase 2: all-gather.
        for step in 0..n - 1 {
            let send_chunk = (rank + 1 + n - step) % n;
            let (s0, s1) = (start(send_chunk), start(send_chunk + 1));
            spare.clear();
            spare.extend_from_slice(&buf[s0..s1]);
            if link_tx.send(std::mem::take(&mut spare)).is_err() {
                return;
            }
            sent += (s1 - s0) * 4;
            let recv_chunk = (rank + n - step) % n;
            let Ok(data) = link_rx.recv() else { return };
            let (r0, r1) = (start(recv_chunk), start(recv_chunk + 1));
            buf[r0..r1].copy_from_slice(&data);
            spare = data;
        }
        if done_tx.send((buf, sent)).is_err() {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn make_buffers(n: usize, len: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 1.0);
                v
            })
            .collect();
        let mut expect = vec![0.0f32; len];
        for b in &bufs {
            for (e, x) in expect.iter_mut().zip(b) {
                *e += *x;
            }
        }
        (bufs, expect)
    }

    #[test]
    fn sum_matches_serial_reduction() {
        for n in [2usize, 3, 4, 8] {
            let t = RingTransport::new(n);
            for len in [1usize, 7, 64, 1000] {
                let (mut bufs, expect) = make_buffers(n, len, len as u64);
                t.all_reduce_sum(&mut bufs).unwrap();
                for (w, b) in bufs.iter().enumerate() {
                    for (i, (&got, &want)) in b.iter().zip(&expect).enumerate()
                    {
                        assert!(
                            (got - want).abs() < 1e-3,
                            "n={n} len={len} worker={w} i={i}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn persistent_workers_survive_many_rounds() {
        // One transport, many rounds of varying payload lengths — the
        // whole point of the persistent ring (no per-round respawn).
        let t = RingTransport::new(4);
        for round in 0..50u64 {
            let len = 1 + (round as usize * 37) % 300;
            let (mut bufs, expect) = make_buffers(4, len, round);
            let stats = t.all_reduce_sum(&mut bufs).unwrap();
            assert_eq!(stats.hops, 6);
            for b in &bufs {
                for (&got, &want) in b.iter().zip(&expect) {
                    assert!((got - want).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn single_worker_noop() {
        let t = RingTransport::new(1);
        let mut bufs = vec![vec![1.0f32, 2.0]];
        let stats = t.all_reduce_sum(&mut bufs).unwrap();
        assert_eq!(stats.hops, 0);
        assert_eq!(stats.bytes_sent_per_worker, 0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn bandwidth_optimal_traffic() {
        let (n, len) = (4usize, 1000usize);
        let t = RingTransport::new(n);
        let (mut bufs, _) = make_buffers(n, len, 9);
        let stats = t.all_reduce_sum(&mut bufs).unwrap();
        let ideal = 2.0 * (n - 1) as f64 / n as f64 * (len * 4) as f64;
        let actual = stats.bytes_sent_per_worker as f64;
        assert!(
            (actual - ideal).abs() / ideal < 0.05,
            "actual {actual} ideal {ideal}"
        );
    }

    #[test]
    fn drop_joins_workers() {
        let t = RingTransport::new(3);
        let (mut bufs, _) = make_buffers(3, 16, 1);
        t.all_reduce_sum(&mut bufs).unwrap();
        drop(t); // must not hang
    }

    #[test]
    fn local_endpoints_cover_the_world() {
        // The in-process ring owns every rank buffer.
        let t = RingTransport::new(4);
        assert_eq!(t.world_size(), 4);
        assert_eq!(t.local_endpoints(), 4);
    }

    #[test]
    fn overlapped_rounds_match_sync_rounds_bitwise() {
        // Two rounds in flight (the depth-2 bucket pipeline), finished
        // FIFO, must equal the same two rounds run synchronously.
        for n in [2usize, 3, 4] {
            let t = RingTransport::new(n);
            assert!(t.supports_overlap());
            let (bufs_a, _) = make_buffers(n, 97, 1);
            let (bufs_b, _) = make_buffers(n, 55, 2);
            let mut sync_a = bufs_a.clone();
            let mut sync_b = bufs_b.clone();
            t.all_reduce_sum(&mut sync_a).unwrap();
            t.all_reduce_sum(&mut sync_b).unwrap();
            t.reduce_begin(bufs_a, 0).unwrap();
            t.reduce_begin(bufs_b, 1).unwrap();
            let (got_a, stats_a) = t.reduce_finish().unwrap();
            let (got_b, _) = t.reduce_finish().unwrap();
            assert_eq!(stats_a.hops, 2 * (n - 1));
            assert_eq!(got_a, sync_a, "n={n} round A");
            assert_eq!(got_b, sync_b, "n={n} round B");
        }
    }

    #[test]
    fn overlap_on_single_worker_is_a_noop() {
        let t = RingTransport::new(1);
        assert!(!t.supports_overlap());
        // Still usable: the serial fallback path may call begin/finish.
        t.reduce_begin(vec![vec![3.0f32, 4.0]], 0).unwrap();
        let (bufs, stats) = t.reduce_finish().unwrap();
        assert_eq!(bufs, vec![vec![3.0f32, 4.0]]);
        assert_eq!(stats.hops, 0);
    }

    #[test]
    fn finish_without_begin_is_an_error() {
        let t = RingTransport::new(2);
        assert!(t.reduce_finish().is_err());
        assert!(t.gather_bytes_finish().is_err());
    }

    #[test]
    fn byte_gather_is_identity_with_simulated_traffic() {
        let t = RingTransport::new(3);
        let mut blocks =
            vec![vec![1u8, 2], vec![3u8, 4, 5, 6], vec![7u8]];
        let want = blocks.clone();
        let bytes = t.all_gather_bytes(&mut blocks, 1).unwrap();
        assert_eq!(blocks, want);
        assert_eq!(bytes, 2 * 4, "(n-1) hops of the largest block");
        t.gather_bytes_begin(blocks, 1).unwrap();
        let (back, bytes2) = t.gather_bytes_finish().unwrap();
        assert_eq!(back, want);
        assert_eq!(bytes2, bytes);
        // World 1 sends nothing.
        let t1 = RingTransport::new(1);
        let mut solo = vec![vec![9u8; 16]];
        assert_eq!(t1.all_gather_bytes(&mut solo, 2).unwrap(), 0);
    }

    #[test]
    fn all_gather_f64_is_identity_in_process() {
        let t = RingTransport::new(3);
        let local = [1.5f64, -2.0, 3.25];
        let mut out = vec![9.0f64; 7]; // stale garbage must be cleared
        let bytes = t.all_gather_f64(&local, &mut out).unwrap();
        assert_eq!(out, local.to_vec());
        assert_eq!(bytes, 0, "nothing crosses a wire in-process");
    }
}
