//! S7: model substrate — LLaMA shape calculus (tiny…7B presets), parameter
//! initialization / store. The forward/backward itself is the compiled L2
//! artifact (python/compile/model.py); Rust owns shapes and state.

pub mod init;
pub mod shapes;

pub use init::{Param, ParamStore};
pub use shapes::{preset, LlamaPreset, ParamShape, LLAMA_1B, LLAMA_7B,
                 PROJ_TYPES, SMALL, TINY};
