//! Parameter initialization + the in-memory parameter store the trainer
//! owns (Rust side of the positional ABI).

use crate::tensor::Mat;
use crate::util::rng::Rng;

use super::shapes::{LlamaPreset, ParamShape};

/// A model parameter: 2-D matrices for projections/embeddings, 1-D
/// vectors for norms.
#[derive(Clone, Debug)]
pub enum Param {
    Matrix(Mat),
    Vector(Vec<f32>),
}

impl Param {
    pub fn numel(&self) -> usize {
        match self {
            Param::Matrix(m) => m.len(),
            Param::Vector(v) => v.len(),
        }
    }

    pub fn as_mat(&self) -> Option<&Mat> {
        match self {
            Param::Matrix(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_mat_mut(&mut self) -> Option<&mut Mat> {
        match self {
            Param::Matrix(m) => Some(m),
            _ => None,
        }
    }

    pub fn flat(&self) -> &[f32] {
        match self {
            Param::Matrix(m) => &m.data,
            Param::Vector(v) => v,
        }
    }

    pub fn flat_mut(&mut self) -> &mut [f32] {
        match self {
            Param::Matrix(m) => &mut m.data,
            Param::Vector(v) => v,
        }
    }
}

/// The full parameter set in ABI order.
pub struct ParamStore {
    pub shapes: Vec<ParamShape>,
    pub params: Vec<Param>,
}

impl ParamStore {
    /// Scaled-gaussian init: std = sqrt(2 / (5 * fan_in)) for matrices
    /// (matching python/compile/model.py::init_params), ones for norms.
    pub fn init(preset: &LlamaPreset, seed: u64) -> ParamStore {
        let shapes = preset.param_shapes();
        let mut rng = Rng::new(seed);
        let params = shapes
            .iter()
            .map(|s| match s.shape.len() {
                1 => Param::Vector(vec![1.0; s.shape[0]]),
                2 => {
                    let std = (2.0 / (5.0 * s.shape[0] as f32)).sqrt();
                    Param::Matrix(Mat::randn(
                        s.shape[0],
                        s.shape[1],
                        std,
                        &mut rng,
                    ))
                }
                _ => unreachable!("params are 1-D or 2-D"),
            })
            .collect();
        ParamStore { shapes, params }
    }

    pub fn numel(&self) -> usize {
        self.params.iter().map(Param::numel).sum()
    }

    pub fn n_projected(&self) -> usize {
        self.shapes.iter().filter(|s| s.proj_type.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shapes::TINY;

    #[test]
    fn init_matches_shape_table() {
        let store = ParamStore::init(&TINY, 0);
        assert_eq!(store.params.len(), store.shapes.len());
        for (p, s) in store.params.iter().zip(&store.shapes) {
            assert_eq!(p.numel(), s.shape.iter().product::<usize>());
        }
        assert_eq!(store.numel(), TINY.param_count());
        assert_eq!(store.n_projected(), TINY.n_projected());
    }

    #[test]
    fn norms_init_to_one_matrices_scaled() {
        let store = ParamStore::init(&TINY, 1);
        let last = store.params.last().unwrap(); // final_norm
        assert!(last.flat().iter().all(|&x| x == 1.0));
        let w = store.params[0].as_mat().unwrap(); // q_proj 64x64
        let std = (w.fro_norm_sq() / w.len() as f64).sqrt();
        let expect = (2.0f64 / (5.0 * 64.0)).sqrt();
        assert!(
            (std - expect).abs() / expect < 0.15,
            "std {std} vs {expect}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = ParamStore::init(&TINY, 7);
        let b = ParamStore::init(&TINY, 7);
        assert_eq!(a.params[3].flat(), b.params[3].flat());
        let c = ParamStore::init(&TINY, 8);
        assert_ne!(a.params[3].flat(), c.params[3].flat());
    }
}
