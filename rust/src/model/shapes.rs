//! LLaMA-architecture shape calculus.
//!
//! Mirrors `python/compile/model.py::param_specs` exactly for the compiled
//! configs, and extends it to the paper-scale presets (LLaMA-1B / 7B) that
//! the memory accountant and the Figure-1/2 layer clustering use — those
//! run at exact paper dimensions even though training itself uses proxies.

/// The seven projection types of Figure 1, in paper order.
pub const PROJ_TYPES: [&str; 7] = [
    "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj",
    "down_proj",
];

#[derive(Clone, Debug, PartialEq)]
pub struct LlamaPreset {
    pub name: &'static str,
    pub vocab: usize,
    pub dim: usize,
    pub hidden: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
}

/// CI-sized config compiled by `make artifacts` (must match model.py TINY).
pub const TINY: LlamaPreset = LlamaPreset {
    name: "tiny",
    vocab: 256,
    dim: 64,
    hidden: 172,
    n_layers: 2,
    n_heads: 4,
    seq_len: 64,
};

pub const SMALL: LlamaPreset = LlamaPreset {
    name: "small",
    vocab: 2048,
    dim: 256,
    hidden: 688,
    n_layers: 4,
    n_heads: 8,
    seq_len: 128,
};

/// The paper's LLaMA-1B: 24 decoder layers (paper §3), GaLore-style dims.
pub const LLAMA_1B: LlamaPreset = LlamaPreset {
    name: "llama-1b",
    vocab: 32_000,
    dim: 2048,
    hidden: 5461,
    n_layers: 24,
    n_heads: 16,
    seq_len: 256,
};

/// LLaMA-7B (Touvron et al., 2023).
pub const LLAMA_7B: LlamaPreset = LlamaPreset {
    name: "llama-7b",
    vocab: 32_000,
    dim: 4096,
    hidden: 11_008,
    n_layers: 32,
    n_heads: 32,
    seq_len: 256,
};

pub fn preset(name: &str) -> Option<LlamaPreset> {
    match name {
        "tiny" => Some(TINY),
        "small" => Some(SMALL),
        "llama-1b" | "1b" => Some(LLAMA_1B),
        "llama-7b" | "7b" => Some(LLAMA_7B),
        _ => None,
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamShape {
    pub name: String,
    pub shape: Vec<usize>,
    /// Projection-layer type index into PROJ_TYPES, or None for dense
    /// (embeddings / norms) params.
    pub proj_type: Option<usize>,
    /// Decoder layer index for 2-D projections.
    pub layer: Option<usize>,
}

impl LlamaPreset {
    /// Projection shape (rows, cols) for a given type index.
    pub fn proj_shape(&self, ty: usize) -> (usize, usize) {
        let (d, h) = (self.dim, self.hidden);
        match PROJ_TYPES[ty] {
            "gate_proj" | "up_proj" => (d, h),
            "down_proj" => (h, d),
            _ => (d, d),
        }
    }

    /// Full parameter list in the python ABI order: projections first
    /// (layer-major), then embed / lm_head / norms.
    pub fn param_shapes(&self) -> Vec<ParamShape> {
        let mut out = Vec::new();
        for layer in 0..self.n_layers {
            for (ti, ty) in PROJ_TYPES.iter().enumerate() {
                let (r, c) = self.proj_shape(ti);
                out.push(ParamShape {
                    name: format!("layers.{layer}.{ty}"),
                    shape: vec![r, c],
                    proj_type: Some(ti),
                    layer: Some(layer),
                });
            }
        }
        out.push(ParamShape {
            name: "embed".into(),
            shape: vec![self.vocab, self.dim],
            proj_type: None,
            layer: None,
        });
        out.push(ParamShape {
            name: "lm_head".into(),
            shape: vec![self.dim, self.vocab],
            proj_type: None,
            layer: None,
        });
        for layer in 0..self.n_layers {
            for nm in ["attn_norm", "mlp_norm"] {
                out.push(ParamShape {
                    name: format!("layers.{layer}.{nm}"),
                    shape: vec![self.dim],
                    proj_type: None,
                    layer: Some(layer),
                });
            }
        }
        out.push(ParamShape {
            name: "final_norm".into(),
            shape: vec![self.dim],
            proj_type: None,
            layer: None,
        });
        out
    }

    pub fn n_projected(&self) -> usize {
        self.n_layers * PROJ_TYPES.len()
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.param_shapes()
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }

    /// Per-step projected-layer GEMM MACs for the fused optimizer update
    /// (3 rank-r contractions per matrix; DESIGN.md §8).
    pub fn opt_step_macs(&self, rank: usize) -> usize {
        (0..PROJ_TYPES.len())
            .map(|ti| {
                let (r_, c_) = self.proj_shape(ti);
                let (m, n) = if r_ <= c_ { (r_, c_) } else { (c_, r_) };
                3 * m * rank.min(m) * n
            })
            .sum::<usize>()
            * self.n_layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_matches_python_abi() {
        // Mirror of python/compile/model.py::param_specs for TINY.
        let shapes = TINY.param_shapes();
        assert_eq!(shapes.len(), 2 * 7 + 2 + 2 * 2 + 1);
        assert_eq!(shapes[0].name, "layers.0.q_proj");
        assert_eq!(shapes[0].shape, vec![64, 64]);
        assert_eq!(shapes[4].name, "layers.0.gate_proj");
        assert_eq!(shapes[4].shape, vec![64, 172]);
        assert_eq!(shapes[6].name, "layers.0.down_proj");
        assert_eq!(shapes[6].shape, vec![172, 64]);
        assert_eq!(shapes[14].name, "embed");
        assert_eq!(shapes[14].shape, vec![256, 64]);
        assert_eq!(shapes.last().unwrap().name, "final_norm");
    }

    #[test]
    fn presets_have_paper_layer_counts() {
        assert_eq!(LLAMA_1B.n_layers, 24); // paper §3: "24 decoder layers"
        assert_eq!(LLAMA_7B.n_layers, 32);
        assert_eq!(LLAMA_1B.n_projected(), 24 * 7);
    }

    #[test]
    fn param_counts_in_expected_ballpark() {
        let b1 = LLAMA_1B.param_count();
        assert!(
            (1.0e9..1.6e9).contains(&(b1 as f64)),
            "1B params = {b1}"
        );
        let b7 = LLAMA_7B.param_count();
        assert!(
            (6.0e9..7.5e9).contains(&(b7 as f64)),
            "7B params = {b7}"
        );
    }

    #[test]
    fn preset_lookup() {
        assert_eq!(preset("1b").unwrap().name, "llama-1b");
        assert_eq!(preset("tiny").unwrap(), TINY);
        assert!(preset("nope").is_none());
    }

    #[test]
    fn projection_shapes_cover_all_types() {
        for ti in 0..7 {
            let (r, c) = LLAMA_1B.proj_shape(ti);
            assert!(r > 0 && c > 0);
        }
        assert_eq!(LLAMA_1B.proj_shape(4), (2048, 5461)); // gate
        assert_eq!(LLAMA_1B.proj_shape(6), (5461, 2048)); // down
    }

    #[test]
    fn opt_step_macs_positive_and_scales_with_rank() {
        let a = LLAMA_1B.opt_step_macs(128);
        let b = LLAMA_1B.opt_step_macs(512);
        assert!(b > a);
    }
}
