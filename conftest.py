"""Repo-root pytest shim: make `pytest python/tests/ -q` work from the
repository root by putting `python/` on sys.path (the tests import the
`compile` package relative to that directory)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
