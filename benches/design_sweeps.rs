//! Ablation benches for the design choices DESIGN.md calls out:
//! rank r, refresh interval T, and geodesic step size η — both their
//! convergence effect (final quadratic-model error) and their per-step /
//! per-refresh cost.
//!
//!   cargo bench --bench design_sweeps

use grasswalk::optim::{
    MatrixOptimizer, ProjectedConfig, ProjectedOptimizer, SubspaceRule,
};
use grasswalk::tensor::Mat;
use grasswalk::util::bench::Bench;
use grasswalk::util::rng::Rng;
use std::time::Instant;

/// Quadratic with a strong low-rank core + noise: the controlled
/// environment in which rank/interval trade-offs are visible.
fn run(cfg: ProjectedConfig, steps: usize, seed: u64) -> (f32, f64) {
    let (m, n) = (48, 96);
    let mut rng = Rng::new(seed);
    let core = grasswalk::optim::grassmann::random_point(m, 6, &mut rng);
    let coeff = Mat::randn(6, n, 2.0, &mut rng);
    let target = grasswalk::tensor::matmul(&core, &coeff);
    let mut w = Mat::zeros(m, n);
    let mut opt = ProjectedOptimizer::new(cfg);
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut g = w.sub(&target);
        g.axpy(0.05, &Mat::randn(m, n, 1.0, &mut rng));
        opt.step(&mut w, &g, &mut rng);
    }
    (w.sub(&target).fro_norm(), t0.elapsed().as_secs_f64())
}

fn main() {
    let steps = 300;
    println!("== design sweeps (quadratic core-subspace model, {steps} \
              steps) ==");

    println!("\n-- rank sweep (GrassWalk, T=20, eta=0.5) --");
    println!("{:<8} {:>12} {:>12} {:>14}", "rank", "final err",
             "time (ms)", "state floats");
    for rank in [1usize, 2, 4, 8, 16, 32] {
        let cfg = ProjectedConfig {
            rank,
            interval: 20,
            alpha: 0.05,
            ..Default::default()
        };
        let (err, secs) = run(cfg.clone(), steps, 1);
        let mut opt = ProjectedOptimizer::new(cfg);
        let mut rng = Rng::new(1);
        let mut w = Mat::zeros(48, 96);
        let g = Mat::randn(48, 96, 1.0, &mut rng);
        opt.step(&mut w, &g, &mut rng);
        println!("{rank:<8} {err:>12.4} {:>12.1} {:>14}", secs * 1e3,
                 opt.state_floats());
    }

    println!("\n-- interval sweep (GrassWalk, rank=8) --");
    println!("{:<8} {:>12} {:>12}", "T", "final err", "time (ms)");
    for interval in [5usize, 10, 25, 50, 100, 1_000_000] {
        let cfg = ProjectedConfig {
            rank: 8,
            interval,
            alpha: 0.05,
            ..Default::default()
        };
        let (err, secs) = run(cfg, steps, 2);
        let label = if interval >= steps { "never".into() }
                    else { interval.to_string() };
        println!("{label:<8} {err:>12.4} {:>12.1}", secs * 1e3);
    }

    println!("\n-- eta sweep (GrassWalk geodesic step size, rank=8, T=20) --");
    println!("{:<8} {:>12}", "eta", "final err");
    for eta in [0.05f32, 0.1, 0.25, 0.5, 1.0, 2.0] {
        let cfg = ProjectedConfig {
            rank: 8,
            interval: 20,
            alpha: 0.05,
            eta,
            ..Default::default()
        };
        let (err, _) = run(cfg, steps, 3);
        println!("{eta:<8} {err:>12.4}");
    }

    println!("\n-- rule cost at refresh (rank=8, refresh EVERY step) --");
    let b = Bench::quick();
    for rule in [SubspaceRule::Svd, SubspaceRule::RandWalk,
                 SubspaceRule::RandJump, SubspaceRule::Track] {
        let mut rng = Rng::new(4);
        let g = Mat::randn(48, 96, 1.0, &mut rng);
        let mut w = Mat::zeros(48, 96);
        let mut opt = ProjectedOptimizer::new(ProjectedConfig {
            rank: 8,
            interval: 1,
            rule,
            ..Default::default()
        });
        opt.step(&mut w, &g, &mut rng);
        b.run(&format!("refresh {}", rule.label()), || {
            opt.step(&mut w, &g, &mut rng);
        });
    }
}
