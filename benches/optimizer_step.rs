//! Bench: per-matrix optimizer step cost across all methods and the two
//! engines (Rust math vs compiled Pallas artifact via PJRT) — the §Perf
//! L3 target is the projected step within 2× of its GEMM roofline.
//!
//!   cargo bench --bench optimizer_step
//!
//! Four additions over the original harness (EXPERIMENTS.md §Workspace
//! and §Pool):
//!
//! 1. **Allocation counting** — a `GlobalAlloc` wrapper counts heap
//!    allocations; the steady-state step of every CPU optimizer except
//!    LDAdam (whose per-step power iteration + QR allocates by design)
//!    is asserted to perform ZERO allocations. Counting runs inside
//!    `pool::run_serial` so pool dispatch (which belongs to the pool,
//!    not the optimizer) cannot leak into the count.
//! 2. **Legacy vs workspace** — `reference_step` is the historical
//!    fully-allocating implementation of the same math; benching it
//!    against `ProjectedOptimizer::step` measures exactly what the
//!    workspace refactor bought on one thread.
//! 3. **Per-matrix parallel stepping** — the trainer-shaped fan-out
//!    (N independent matrices across the pool) vs the sequential loop.
//! 4. **Persistent-pool steady state** — THREADED `parallel_chunks` /
//!    `parallel_for` regions (not `run_serial`) are hard-asserted to
//!    perform 0 thread spawns (`pool::spawn_count`) and 0 heap
//!    allocations across every thread in the process: the fork-join
//!    dispatch itself is free once the pool is warm (ISSUE 3).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use grasswalk::optim::projected::reference_step;
use grasswalk::optim::{
    CpuMatrixOptimizer, MatrixOptimizer, Method, SubspaceRule,
};
use grasswalk::runtime::Engine;
use grasswalk::tensor::{matmul, matmul_tn, Mat};
use grasswalk::util::alloc::{self, MemDomain};
use grasswalk::util::bench::{header, Bench};
use grasswalk::util::benchgate::Gate;
use grasswalk::util::pool;
use grasswalk::util::rng::Rng;

/// Allocations performed by `f` process-wide, via the library-level
/// counting allocator in `grasswalk::util::alloc` (which replaced this
/// bench's hand-rolled `GlobalAlloc` wrapper). Single-threaded callers
/// only — run under `pool::run_serial`.
fn alloc_count(f: impl FnOnce()) -> u64 {
    alloc::count_process(f)
}

fn main() {
    let b = Bench::default();
    let mut rng = Rng::new(0);
    let mut gate = Gate::new("optimizer_step");
    println!("== optimizer step (per matrix) ==");
    println!("{}", header());

    for &(m, n, r) in &[(64usize, 172usize, 16usize), (256, 688, 64)] {
        println!("-- shape {m}x{n}, rank {r} --");
        let g = Mat::randn(m, n, 1.0, &mut rng);

        // Roofline reference: the 3 rank-r GEMMs alone.
        let s = grasswalk::tensor::orthonormalize(
            &Mat::randn(m, r, 1.0, &mut rng));
        let stats = b.run(&format!("gemm roofline (3 thin)   {m}x{n}"), || {
            let gt = matmul_tn(&s, &g);
            let _ = std::hint::black_box(matmul(&s, &gt));
            let _ = std::hint::black_box(matmul(&s, &gt));
        });
        gate.time(&stats);
        let roofline = stats.median;

        // Legacy path: the historical allocating implementation of the
        // projected+AO+RS step (reference_step is that code, preserved
        // verbatim as the numerical oracle).
        let legacy = {
            let mut w = Mat::randn(m, n, 1.0, &mut rng);
            let mut ms = Mat::zeros(r, n);
            let mut vs = Mat::zeros(r, n);
            let rot = Mat::eye(r);
            let mut lam = 0.0f32;
            let mut t = 1usize;
            b.run(&format!("legacy alloc step (ref)  {m}x{n}"), || {
                let (w2, m2, v2, l2) = reference_step(
                    &w, &g, &s, &ms, &vs, &rot, t, lam, false, 1e-3, 0.9,
                    0.999, 1e-8, 1.01,
                );
                w = w2;
                ms = m2;
                vs = v2;
                lam = l2;
                t += 1;
            })
        };
        gate.time(&legacy);

        let mut grass_median = None;
        for method in Method::all() {
            let mut opt = method.build(r, 1_000_000, 1e-3, 1000);
            let mut w = Mat::randn(m, n, 1.0, &mut rng);
            let mut step_rng = Rng::new(7);
            // Two warmup steps: t=1 initializes state (refresh), t=2
            // sizes every steady-state workspace buffer.
            opt.step(&mut w, &g, &mut step_rng);
            opt.step(&mut w, &g, &mut step_rng);

            // Zero-allocation assertion for the steady state, measured
            // on the serial path so pool spawns don't pollute the count.
            let allocs = pool::run_serial(|| {
                alloc_count(|| opt.step(&mut w, &g, &mut step_rng))
            });
            if *method == Method::LdAdam {
                println!(
                    "    {: <24} steady-state allocs/step: {} \
                     (per-step QR; documented exception)",
                    method.label(),
                    allocs
                );
            } else {
                assert_eq!(
                    allocs, 0,
                    "{}: steady-state step must not allocate",
                    method.label()
                );
                gate.counter(
                    &format!("steady allocs {} {m}x{n}", method.label()),
                    allocs,
                );
            }

            let st = b.run(
                &format!("{:<24} {m}x{n}", method.label()),
                || {
                    opt.step(&mut w, &g, &mut step_rng);
                },
            );
            gate.time(&st);
            if *method == Method::GrassWalk {
                grass_median = Some(st.median);
                println!(
                    "    -> grasswalk steady-state vs roofline: {:.2}x",
                    st.median.as_secs_f64() / roofline.as_secs_f64()
                );
            }
        }
        if let Some(gm) = grass_median {
            println!(
                "    -> workspace vs legacy single-thread speedup: {:.2}x",
                legacy.median.as_secs_f64() / gm.as_secs_f64()
            );
        }

        // Refresh cost per rule (the every-T step), all routed through
        // the shared subspace engine.
        for rule in [SubspaceRule::Svd, SubspaceRule::RandWalk,
                     SubspaceRule::RandJump, SubspaceRule::Track] {
            let mut opt = grasswalk::optim::ProjectedOptimizer::new(
                grasswalk::optim::ProjectedConfig {
                    rank: r,
                    interval: 1, // refresh EVERY step
                    rule,
                    ..Default::default()
                },
            );
            let mut w = Mat::randn(m, n, 1.0, &mut rng);
            let mut step_rng = Rng::new(8);
            opt.step(&mut w, &g, &mut step_rng);
            let st = b.run(
                &format!("refresh-every-step {:<8} {m}x{n}", rule.label()),
                || {
                    opt.step(&mut w, &g, &mut step_rng);
                },
            );
            gate.time(&st);
        }

        // Shared-seed regeneration — the comm collective's free basis
        // (QR of a seeded gaussian; the per-round cost every lowrank
        // worker pays locally instead of shipping basis bytes). Same
        // provider GrassJump's refresh uses, so comparing this row to
        // `refresh-every-step jump` isolates the SVD-vs-regen split.
        {
            let mut round = 0u64;
            let st = b.run(&format!("refresh shared-seed regen {m}x{n}"), || {
                let basis = grasswalk::subspace::shared_seed_basis(
                    42, round, 0, m, r,
                );
                std::hint::black_box(&basis);
                round = round.wrapping_add(1);
            });
            gate.time(&st);
        }
    }

    // Per-matrix parallel stepping: the trainer's fan-out shape. N
    // independent (optimizer, W, G, RNG) tuples stepped sequentially vs
    // across the pool — scaling comes on top of the single-thread
    // workspace win because steps share nothing.
    println!("-- per-matrix parallel stepping ({} threads) --",
             pool::threads());
    let (m, n, r) = (256usize, 688usize, 64usize);
    for n_mats in [4usize, 16] {
        struct Slot {
            opt: Box<dyn CpuMatrixOptimizer>,
            w: Mat,
            g: Mat,
            rng: Rng,
        }
        let mut slots: Vec<Slot> = (0..n_mats)
            .map(|i| {
                let mut srng = Rng::new(100 + i as u64);
                let mut slot = Slot {
                    opt: Method::GrassWalk.build_cpu(r, 1_000_000, 1e-3,
                                                     1000),
                    w: Mat::randn(m, n, 1.0, &mut srng),
                    g: Mat::randn(m, n, 1.0, &mut srng),
                    rng: srng,
                };
                let Slot { opt, w, g, rng } = &mut slot;
                opt.step(w, g, rng);
                opt.step(w, g, rng);
                slot
            })
            .collect();
        let seq = b.run(&format!("sequential {n_mats} matrices"), || {
            for s in slots.iter_mut() {
                s.opt.step(&mut s.w, &s.g, &mut s.rng);
            }
        });
        gate.time(&seq);
        let par = b.run(&format!("pool fan-out {n_mats} matrices"), || {
            pool::parallel_items(&mut slots, |_, s| {
                s.opt.step(&mut s.w, &s.g, &mut s.rng);
            });
        });
        gate.time(&par);
        println!(
            "    -> parallel speedup {n_mats} matrices: {:.2}x",
            seq.median.as_secs_f64() / par.median.as_secs_f64()
        );
    }

    // Persistent-pool steady state (ISSUE 3 acceptance): a THREADED
    // parallel section must spawn no threads and allocate nothing once
    // the pool is warm. Measured OUTSIDE run_serial so the real
    // dispatch path runs; the counting allocator is global, so worker
    // threads' allocations (there must be none) are counted too.
    println!(
        "-- persistent pool steady state ({} threads) --",
        pool::threads()
    );
    {
        let n = 1usize << 14;
        let mut buf = vec![0u64; n];
        let sink = AtomicU64::new(0);
        // Warm: the first threaded call lazily spawns the workers.
        pool::parallel_chunks(&mut buf, 256, |i, piece| {
            for p in piece.iter_mut() {
                *p = i as u64;
            }
        });
        pool::parallel_for(n, 256, |i| {
            sink.fetch_add(i as u64, Ordering::Relaxed);
        });
        let spawns_before = pool::spawn_count();
        let allocs = alloc_count(|| {
            for round in 0..16u64 {
                pool::parallel_chunks(&mut buf, 256, |i, piece| {
                    for p in piece.iter_mut() {
                        *p = p.wrapping_add(i as u64 + round);
                    }
                });
                pool::parallel_for(n, 256, |i| {
                    sink.fetch_add(i as u64, Ordering::Relaxed);
                });
            }
        });
        let spawned = pool::spawn_count() - spawns_before;
        println!(
            "    threaded parallel_chunks+parallel_for x16: \
             {allocs} allocs, {spawned} spawns"
        );
        assert_eq!(
            spawned, 0,
            "steady-state parallel sections must not spawn threads"
        );
        assert_eq!(
            allocs, 0,
            "steady-state parallel dispatch must not allocate"
        );
        gate.counter("pool steady-state allocs (x16 rounds)", allocs);
        gate.counter("pool steady-state spawns (x16 rounds)", spawned);
        // Fork-join latency of a no-op region: the fixed cost every
        // GEMM tile / fan-out now pays instead of threads() spawns.
        let st = b.run("pool dispatch (no-op region)", || {
            pool::parallel_for(n, 256, |_| {});
        });
        gate.time(&st);
        std::hint::black_box(&buf);
        std::hint::black_box(sink.load(Ordering::Relaxed));
    }

    // Traced steady state (ISSUE 7 acceptance): with tracing enabled, a
    // span-wrapped GrassWalk step plus the per-step collector drain must
    // still allocate NOTHING once the ring and collector are warm. The
    // warmup iteration absorbs the one-time costs (thread-ring
    // registration, collector track-name table); steady state is pure
    // clock reads, fixed-slot ring pushes, and histogram increments.
    println!("-- traced step (trace enabled) --");
    {
        use grasswalk::trace::{self, Phase};
        let (m, n, r) = (64usize, 172usize, 16usize);
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let mut opt = Method::GrassWalk.build(r, 1_000_000, 1e-3, 1000);
        let mut w = Mat::randn(m, n, 1.0, &mut rng);
        let mut step_rng = Rng::new(11);
        opt.step(&mut w, &g, &mut step_rng);
        opt.step(&mut w, &g, &mut step_rng);

        let off = b.run(&format!("untraced grasswalk step  {m}x{n}"), || {
            opt.step(&mut w, &g, &mut step_rng);
        });
        gate.time(&off);

        trace::set_enabled(true);
        let mut collector = trace::TraceCollector::new(false);
        let mut traced_step =
            |opt: &mut Box<dyn MatrixOptimizer>,
             w: &mut Mat,
             step_rng: &mut Rng,
             collector: &mut trace::TraceCollector| {
                let st = trace::start();
                {
                    let _sp = trace::span(Phase::OptStep);
                    opt.step(w, &g, step_rng);
                }
                st.record(Phase::Step);
                collector.drain();
            };
        // Warmup drain: registers this thread's ring and sizes the
        // collector's per-track tables (the only allocating calls).
        traced_step(&mut opt, &mut w, &mut step_rng, &mut collector);

        let allocs = pool::run_serial(|| {
            alloc_count(|| {
                traced_step(&mut opt, &mut w, &mut step_rng, &mut collector)
            })
        });
        assert_eq!(
            allocs, 0,
            "traced steady-state step (span + ring push + drain) must \
             not allocate"
        );
        gate.counter(
            &format!("traced steady allocs (span+drain) {m}x{n}"),
            allocs,
        );

        let on = b.run(&format!("traced grasswalk step    {m}x{n}"), || {
            traced_step(&mut opt, &mut w, &mut step_rng, &mut collector);
        });
        gate.time(&on);
        let delta_ns = on
            .median
            .saturating_sub(off.median)
            .as_nanos() as f64;
        println!(
            "    -> tracing overhead per traced step: {delta_ns:.0} ns \
             ({:.2}% of untraced)",
            100.0 * delta_ns / off.median.as_nanos().max(1) as f64
        );
        gate.time_ns(
            &format!("trace overhead (traced - untraced) {m}x{n}"),
            delta_ns,
        );
        trace::set_enabled(false);
    }

    // Traced + mem-diag steady state (ISSUE 9 acceptance): tracing AND
    // per-domain byte tracking on, with the full per-step mem pipeline —
    // domain scope, collector drain + memory counter sample, and all 20
    // `mem/*` series pushed through interned ids — must stay 0-alloc
    // once the ring, collector, sample store, and series capacity are
    // warm. This is the contract that lets `--trace --mem-diag` run on
    // the hot path without perturbing what it measures.
    println!("-- traced + mem-diag step --");
    {
        use grasswalk::metrics::Recorder;
        use grasswalk::trace::{self, Phase};
        let (m, n, r) = (64usize, 172usize, 16usize);
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let mut opt = Method::GrassWalk.build(r, 1_000_000, 1e-3, 1000);
        let mut w = Mat::randn(m, n, 1.0, &mut rng);
        let mut step_rng = Rng::new(13);
        opt.step(&mut w, &g, &mut step_rng);
        opt.step(&mut w, &g, &mut step_rng);

        alloc::set_tracking(true);
        trace::set_enabled(true);
        let mut collector = trace::TraceCollector::new(false);
        let mut rec = Recorder::new("bench-mem-diag");
        let mem_ids: Vec<(_, _)> = MemDomain::ALL
            .iter()
            .map(|d| {
                (
                    rec.series_id(&format!("mem/{}/live", d.label())),
                    rec.series_id(&format!("mem/{}/peak", d.label())),
                )
            })
            .collect();
        let proc_ids = (
            rec.series_id("mem/process/live"),
            rec.series_id("mem/process/peak"),
        );

        let mut step_no = 0usize;
        let mut mem_step = |opt: &mut Box<dyn MatrixOptimizer>,
                            w: &mut Mat,
                            step_rng: &mut Rng,
                            collector: &mut trace::TraceCollector,
                            rec: &mut Recorder| {
            let st = trace::start();
            {
                let _dom = alloc::scope(MemDomain::OptimState);
                let _sp = trace::span(Phase::OptStep);
                opt.step(w, &g, step_rng);
            }
            st.record(Phase::Step);
            collector.drain();
            collector.record_mem_sample(trace::now_ns(), alloc::live_all());
            for (d, &(il, ip)) in MemDomain::ALL.iter().zip(&mem_ids) {
                rec.push_id(il, step_no, alloc::live_bytes(*d) as f64);
                rec.push_id(ip, step_no, alloc::peak_bytes(*d) as f64);
            }
            rec.push_id(
                proc_ids.0,
                step_no,
                alloc::process_live_bytes() as f64,
            );
            rec.push_id(
                proc_ids.1,
                step_no,
                alloc::process_peak_bytes() as f64,
            );
            step_no += 1;
        };
        // Warmup: ring registration, collector tables, the bounded
        // memory-sample store, and enough series capacity that the
        // measured steps below cannot cross a Vec growth boundary.
        for _ in 0..70 {
            mem_step(&mut opt, &mut w, &mut step_rng, &mut collector,
                     &mut rec);
        }

        let allocs = pool::run_serial(|| {
            alloc_count(|| {
                for _ in 0..10 {
                    mem_step(&mut opt, &mut w, &mut step_rng,
                             &mut collector, &mut rec);
                }
            })
        });
        assert_eq!(
            allocs, 0,
            "traced + mem-diag steady-state step (scope + drain + \
             sample + 20 series pushes) must not allocate"
        );
        gate.counter(
            &format!("traced+mem-diag steady allocs {m}x{n}"),
            allocs,
        );

        let st = b.run(&format!("traced+mem-diag step     {m}x{n}"), || {
            mem_step(&mut opt, &mut w, &mut step_rng, &mut collector,
                     &mut rec);
        });
        gate.time(&st);
        trace::set_enabled(false);
    }

    // PJRT fused-kernel path, if artifacts exist.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = Arc::new(Engine::new(dir).unwrap());
        let (m, n, r) = (64usize, 172usize, 16usize);
        let mut opt = grasswalk::coordinator::PjrtProjected::new(
            engine, SubspaceRule::RandJump, r, 1_000_000, 0.5);
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let mut w = Mat::randn(m, n, 1.0, &mut rng);
        let mut step_rng = Rng::new(9);
        opt.step(&mut w, &g, &mut step_rng);
        let st = b.run(&format!("pjrt fused opt_step      {m}x{n}"), || {
            opt.step(&mut w, &g, &mut step_rng);
        });
        gate.time(&st);
    } else {
        eprintln!("(skipping PJRT engine rows: run `make artifacts`)");
    }

    if let Err(e) = gate.finish() {
        eprintln!("{e}");
        std::process::exit(1);
    }
}
