//! Bench: per-matrix optimizer step cost across all methods and the two
//! engines (Rust math vs compiled Pallas artifact via PJRT) — the §Perf
//! L3 target is the projected step within 2× of its GEMM roofline.
//!
//!   cargo bench --bench optimizer_step

use std::sync::Arc;

use grasswalk::optim::{Method, MatrixOptimizer, SubspaceRule};
use grasswalk::runtime::Engine;
use grasswalk::tensor::{Mat, matmul, matmul_tn};
use grasswalk::util::bench::{header, Bench};
use grasswalk::util::rng::Rng;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::new(0);
    println!("== optimizer step (per matrix) ==");
    println!("{}", header());

    for &(m, n, r) in &[(64usize, 172usize, 16usize), (256, 688, 64)] {
        println!("-- shape {m}x{n}, rank {r} --");
        let g = Mat::randn(m, n, 1.0, &mut rng);

        // Roofline reference: the 3 rank-r GEMMs alone.
        let s = grasswalk::tensor::orthonormalize(
            &Mat::randn(m, r, 1.0, &mut rng));
        let stats = b.run(&format!("gemm roofline (3 thin)   {m}x{n}"), || {
            let gt = matmul_tn(&s, &g);
            let _ = std::hint::black_box(matmul(&s, &gt));
            let _ = std::hint::black_box(matmul(&s, &gt));
        });
        let roofline = stats.median;

        for method in Method::all() {
            let mut opt = method.build(r, 1_000_000, 1e-3, 1000);
            let mut w = Mat::randn(m, n, 1.0, &mut rng);
            let mut step_rng = Rng::new(7);
            // init
            opt.step(&mut w, &g, &mut step_rng);
            let st = b.run(
                &format!("{:<24} {m}x{n}", method.label()),
                || {
                    opt.step(&mut w, &g, &mut step_rng);
                },
            );
            if *method == Method::GrassWalk {
                println!(
                    "    -> grasswalk steady-state vs roofline: {:.2}x",
                    st.median.as_secs_f64() / roofline.as_secs_f64()
                );
            }
        }

        // Refresh cost per rule (the every-T step).
        for rule in [SubspaceRule::Svd, SubspaceRule::RandWalk,
                     SubspaceRule::RandJump, SubspaceRule::Track] {
            let mut opt = grasswalk::optim::ProjectedOptimizer::new(
                grasswalk::optim::ProjectedConfig {
                    rank: r,
                    interval: 1, // refresh EVERY step
                    rule,
                    ..Default::default()
                },
            );
            let mut w = Mat::randn(m, n, 1.0, &mut rng);
            let mut step_rng = Rng::new(8);
            opt.step(&mut w, &g, &mut step_rng);
            b.run(
                &format!("refresh-every-step {:<8} {m}x{n}", rule.label()),
                || {
                    opt.step(&mut w, &g, &mut step_rng);
                },
            );
        }
    }

    // PJRT fused-kernel path, if artifacts exist.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = Arc::new(Engine::new(dir).unwrap());
        let (m, n, r) = (64usize, 172usize, 16usize);
        let mut opt = grasswalk::coordinator::PjrtProjected::new(
            engine, SubspaceRule::RandJump, r, 1_000_000, 0.5);
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let mut w = Mat::randn(m, n, 1.0, &mut rng);
        let mut step_rng = Rng::new(9);
        opt.step(&mut w, &g, &mut step_rng);
        b.run(&format!("pjrt fused opt_step      {m}x{n}"), || {
            opt.step(&mut w, &g, &mut step_rng);
        });
    } else {
        eprintln!("(skipping PJRT engine rows: run `make artifacts`)");
    }
}
