//! Bench: the linalg substrate (S1) — GEMM variants, QR, SVD, rSVD at the
//! paper's layer geometries. Feeds the §Perf iteration log: the optimizer
//! hot path is 3 thin GEMMs per matrix, and subspace refreshes are
//! QR/SVD/rSVD-bound.
//!
//!   cargo bench --bench linalg

use grasswalk::tensor::{
    matmul, matmul_tn, qr_thin, rsvd, svd_thin, Mat,
};
use grasswalk::util::bench::{header, Bench};
use grasswalk::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let b = Bench::default();
    println!("== linalg substrate ==");
    println!("{}", header());

    // Proxy layer geometry (compiled model) and a 1B-ish slice.
    for &(m, n, r) in &[(64usize, 172usize, 16usize), (256, 688, 64),
                        (512, 1365, 128)] {
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let s = grasswalk::tensor::orthonormalize(
            &Mat::randn(m, r, 1.0, &mut rng));
        let gt = matmul_tn(&s, &g);

        b.run(&format!("project S^T G            {m}x{n} r{r}"), || {
            std::hint::black_box(matmul_tn(&s, &g));
        });
        b.run(&format!("backproject S Gt         {m}x{n} r{r}"), || {
            std::hint::black_box(matmul(&s, &gt));
        });
        b.run(&format!("qr_thin                  {m}x{r}"), || {
            std::hint::black_box(qr_thin(
                &Mat::randn(m, r, 1.0, &mut Rng::new(1))));
        });
        b.run(&format!("rsvd (r, +4, p0)         {m}x{r}"), || {
            let x = Mat::randn(m, r, 1.0, &mut Rng::new(2));
            std::hint::black_box(rsvd(&x, r, 4, 0, &mut Rng::new(3)));
        });
    }

    // Full SVD — the GaLore refresh cost (paper: "computationally heavy").
    for &(m, n) in &[(64usize, 172usize), (128, 344), (256, 688)] {
        let g = Mat::randn(m, n, 1.0, &mut rng);
        b.run(&format!("svd_thin (GaLore refresh) {m}x{n}"), || {
            std::hint::black_box(svd_thin(&g));
        });
    }

    // GEMM scaling for the roofline estimate.
    for &d in &[64usize, 128, 256, 512] {
        let a = Mat::randn(d, d, 1.0, &mut rng);
        let c = Mat::randn(d, d, 1.0, &mut rng);
        let stats = b.run(&format!("gemm square              {d}x{d}"), || {
            std::hint::black_box(matmul(&a, &c));
        });
        let flops = 2.0 * (d as f64).powi(3);
        println!(
            "    -> {:.2} GFLOP/s",
            flops / stats.median.as_secs_f64() / 1e9
        );
    }
}
