//! Bench: the linalg substrate (S1) — GEMM variants, QR, SVD, rSVD at the
//! paper's layer geometries. Feeds the §Perf iteration log: the optimizer
//! hot path is 3 thin GEMMs per matrix, and subspace refreshes are
//! QR/SVD/rSVD-bound.
//!
//! Every row feeds the benchmark-regression gate (util::benchgate): the
//! run is compared against the committed BENCH_linalg.json and the
//! binary exits nonzero on a regression past the noise tolerance.
//!
//!   cargo bench --bench linalg                        # gate against baseline
//!   GRASSWALK_BENCH_WRITE=1 cargo bench --bench linalg # rewrite baseline
//!
//! The thin-projection sweep (r ∈ {16, 32, 128}) mirrors the shapes the
//! optimizer actually runs — `SᵀG` (r×m · m×n) and `S·G̃` (m×r · r×n) at
//! real layer dims — so kernel-tier changes are judged on those, not
//! just square GEMMs. GFLOP/s columns use flops = 2·r·m·n per call.

use grasswalk::tensor::{
    matmul, matmul_into, matmul_tn, matmul_tn_into, qr_thin, rsvd, svd_thin,
    Mat,
};
use grasswalk::util::bench::{header, Bench};
use grasswalk::util::benchgate::Gate;
use grasswalk::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let b = Bench::default();
    let mut gate = Gate::new("linalg");
    println!("== linalg substrate ==");
    println!("{}", header());

    // Proxy layer geometry (compiled model) and a 1B-ish slice.
    for &(m, n, r) in &[(64usize, 172usize, 16usize), (256, 688, 64),
                        (512, 1365, 128)] {
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let s = grasswalk::tensor::orthonormalize(
            &Mat::randn(m, r, 1.0, &mut rng));
        let gt = matmul_tn(&s, &g);

        let st = b.run(&format!("project S^T G            {m}x{n} r{r}"), || {
            std::hint::black_box(matmul_tn(&s, &g));
        });
        gate.time_with_flops(&st, 2 * r * m * n);
        let st = b.run(&format!("backproject S Gt         {m}x{n} r{r}"), || {
            std::hint::black_box(matmul(&s, &gt));
        });
        gate.time_with_flops(&st, 2 * m * r * n);
        let st = b.run(&format!("qr_thin                  {m}x{r}"), || {
            std::hint::black_box(qr_thin(
                &Mat::randn(m, r, 1.0, &mut Rng::new(1))));
        });
        gate.time(&st);
        let st = b.run(&format!("rsvd (r, +4, p0)         {m}x{r}"), || {
            let x = Mat::randn(m, r, 1.0, &mut Rng::new(2));
            std::hint::black_box(rsvd(&x, r, 4, 0, &mut Rng::new(3)));
        });
        gate.time(&st);
    }

    // Thin projection sweep at fixed layer slabs: the gate's primary
    // kernel-tier rows. Warm `_into` buffers so the loop measures the
    // kernel, not allocation.
    println!("-- thin projection sweep (kernel tier) --");
    for &(m, n) in &[(256usize, 688usize), (512, 1365)] {
        for &r in &[16usize, 32, 128] {
            let g = Mat::randn(m, n, 1.0, &mut rng);
            let s = grasswalk::tensor::orthonormalize(
                &Mat::randn(m, r, 1.0, &mut rng));
            let gt = matmul_tn(&s, &g);
            let mut proj = Mat::default();
            let mut back = Mat::default();
            let flops = 2 * r * m * n;

            let st = b.run(&format!("thin S^T G               r{r} {m}x{n}"), || {
                matmul_tn_into(&s, &g, &mut proj);
                std::hint::black_box(&proj);
            });
            gate.time_with_flops(&st, flops);
            println!(
                "    -> {:.2} GFLOP/s",
                flops as f64 / st.median.as_secs_f64() / 1e9
            );

            let st = b.run(&format!("thin S Gt                r{r} {m}x{n}"), || {
                matmul_into(&s, &gt, &mut back);
                std::hint::black_box(&back);
            });
            gate.time_with_flops(&st, flops);
            println!(
                "    -> {:.2} GFLOP/s",
                flops as f64 / st.median.as_secs_f64() / 1e9
            );
        }
    }

    // Full SVD — the GaLore refresh cost (paper: "computationally heavy").
    for &(m, n) in &[(64usize, 172usize), (128, 344), (256, 688)] {
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let st = b.run(&format!("svd_thin (GaLore refresh) {m}x{n}"), || {
            std::hint::black_box(svd_thin(&g));
        });
        gate.time(&st);
    }

    // GEMM scaling for the roofline estimate.
    for &d in &[64usize, 128, 256, 512] {
        let a = Mat::randn(d, d, 1.0, &mut rng);
        let c = Mat::randn(d, d, 1.0, &mut rng);
        let stats = b.run(&format!("gemm square              {d}x{d}"), || {
            std::hint::black_box(matmul(&a, &c));
        });
        let flops = 2 * d * d * d;
        gate.time_with_flops(&stats, flops);
        println!(
            "    -> {:.2} GFLOP/s",
            flops as f64 / stats.median.as_secs_f64() / 1e9
        );
    }

    // Machine-independent gate row: the steady-state `_into` projection
    // kernels are zero-alloc once their output buffers are warm — the
    // contract the packed-GEMM tier and the optimizer workspace rely
    // on. Counted serially so pool dispatch stays out of the number.
    {
        let (m, n, r) = (256usize, 688, 32);
        let g = Mat::randn(m, n, 1.0, &mut rng);
        let s = grasswalk::tensor::orthonormalize(
            &Mat::randn(m, r, 1.0, &mut rng));
        let gt = matmul_tn(&s, &g);
        let mut proj = Mat::default();
        let mut back = Mat::default();
        matmul_tn_into(&s, &g, &mut proj);
        matmul_into(&s, &gt, &mut back);
        let allocs = grasswalk::util::pool::run_serial(|| {
            grasswalk::util::alloc::count_process(|| {
                for _ in 0..16 {
                    matmul_tn_into(&s, &g, &mut proj);
                    matmul_into(&s, &gt, &mut back);
                }
            })
        });
        assert_eq!(
            allocs, 0,
            "steady-state thin `_into` kernels must not allocate"
        );
        gate.counter("thin `_into` kernel allocs (x16 rounds)", allocs);
        println!("thin `_into` kernels: 0 allocs across 16 warm rounds");
    }

    if let Err(e) = gate.finish() {
        eprintln!("{e}");
        std::process::exit(1);
    }
}
