//! Bench: coordinator substrates — ring all-reduce scaling, persistent
//! worker-pool fork-join, loader throughput/backpressure, and the full
//! train-step breakdown (fwd/bwd vs optimizer vs data) that the §Perf
//! L3 pass optimizes against.
//!
//!   cargo bench --bench coordinator

use std::sync::Arc;
use std::time::Instant;

use grasswalk::comm::{
    build_collective, Collective, CommMode, GradLayout, RingTransport,
    Transport,
};
use grasswalk::coordinator::{Ring, TrainConfig, Trainer};
use grasswalk::data::{CorpusConfig, Loader, SyncLoader};
use grasswalk::model::shapes::TINY;
use grasswalk::optim::Method;
use grasswalk::runtime::Engine;
use grasswalk::util::bench::{header, throughput, Bench};
use grasswalk::util::pool;

fn main() -> anyhow::Result<()> {
    let b = Bench::default();
    println!("== coordinator substrates ==");
    println!("{}", header());

    // Ring all-reduce scaling in world size and payload.
    for &workers in &[2usize, 4, 8] {
        for &len in &[1 << 12, 1 << 16, 1 << 20] {
            let ring = Ring::new(workers);
            let stats = b.run(
                &format!("ring all-reduce w={workers} len={len}"),
                || {
                    let mut bufs: Vec<Vec<f32>> =
                        (0..workers).map(|_| vec![1.0f32; len]).collect();
                    std::hint::black_box(ring.all_reduce_sum(&mut bufs));
                },
            );
            let bytes = 2.0 * (workers - 1) as f64 / workers as f64
                * (len * 4) as f64;
            println!(
                "    -> {:.2} GB/s effective per worker",
                bytes / stats.median.as_secs_f64() / 1e9
            );
        }
    }

    // Persistent ring transport vs the legacy per-call respawn above:
    // same schedule, but threads + links are created once, so the delta
    // is pure spawn overhead removed from every training step.
    for &workers in &[2usize, 4, 8] {
        for &len in &[1usize << 12, 1 << 16, 1 << 20] {
            let transport = RingTransport::new(workers);
            let stats = b.run(
                &format!("persistent ring w={workers} len={len}"),
                || {
                    let mut bufs: Vec<Vec<f32>> =
                        (0..workers).map(|_| vec![1.0f32; len]).collect();
                    std::hint::black_box(
                        transport.all_reduce_sum(&mut bufs),
                    );
                },
            );
            let bytes = 2.0 * (workers - 1) as f64 / workers as f64
                * (len * 4) as f64;
            println!(
                "    -> {:.2} GB/s effective per worker (no respawn)",
                bytes / stats.median.as_secs_f64() / 1e9
            );
        }
    }

    // Persistent worker-pool fork-join (the primitive under every GEMM
    // tile, per-matrix optimizer fan-out and per-worker fwd/bwd
    // fan-out): steady-state dispatch reuses long-lived workers, so the
    // spawn delta across every row below must be zero.
    {
        let mut warm = vec![0f32; 1 << 12];
        pool::parallel_chunks(&mut warm, 1 << 8, |_, p| {
            for x in p.iter_mut() {
                *x += 1.0;
            }
        });
        let spawns_before = pool::spawn_count();
        for &len in &[1usize << 12, 1 << 16, 1 << 20] {
            let mut v = vec![0f32; len];
            let chunk = len.div_ceil(pool::threads().max(1)).max(1);
            let stats = b.run(
                &format!(
                    "pool parallel_chunks t={} len={len}",
                    pool::threads()
                ),
                || {
                    pool::parallel_chunks(&mut v, chunk, |_, piece| {
                        for x in piece.iter_mut() {
                            *x += 1.0;
                        }
                    });
                },
            );
            println!(
                "    -> {:.2} GB/s touched",
                (len * 4) as f64 / stats.median.as_secs_f64() / 1e9
            );
        }
        assert_eq!(
            pool::spawn_count() - spawns_before,
            0,
            "steady-state pool dispatch must not spawn threads"
        );
        println!("    -> spawns across all rows: 0 (persistent pool)");
    }

    // Collective regimes on the proxy-model (TINY) gradient layout:
    // dense full exchange vs shared-seed low-rank factors.
    let shapes: Vec<Vec<usize>> =
        TINY.param_shapes().iter().map(|p| p.shape.clone()).collect();
    let layout = GradLayout::from_shapes(&shapes);
    for mode in [CommMode::Dense, CommMode::LowRank] {
        let mut coll = build_collective(mode, 4, 16, 0);
        let mut payload = 0usize;
        let s = b.run(
            &format!("collective {} w=4 (TINY layout)", mode.label()),
            || {
                let mut bufs: Vec<Vec<f32>> = (0..4)
                    .map(|_| vec![1.0f32; layout.total_floats])
                    .collect();
                let stats =
                    coll.all_reduce_mean(&mut bufs, &layout).unwrap();
                payload = stats.bytes_per_worker;
                std::hint::black_box(bufs);
            },
        );
        println!(
            "    -> {payload} wire bytes/worker/step, {:.1} rounds/s",
            throughput(1, s.median)
        );
    }

    // Loader: sync vs prefetching throughput.
    let cfg = CorpusConfig::default();
    let mut sync = SyncLoader::new(cfg.clone(), 0, 1, 8, 65);
    let s = b.run("loader sync 8x65", || {
        std::hint::black_box(sync.next());
    });
    println!(
        "    -> {:.0} batches/s",
        throughput(1, s.median)
    );
    let pre = Loader::spawn(cfg, 0, 1, 8, 65, 8);
    // Drain warm queue then measure steady-state.
    for _ in 0..8 {
        let _ = pre.next();
    }
    let s = b.run("loader prefetch 8x65", || {
        std::hint::black_box(pre.next());
    });
    println!(
        "    -> {:.0} batches/s (hides generation latency)",
        throughput(1, s.median)
    );

    // Full train-step breakdown on the compiled model.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(skipping train-step rows: run `make artifacts`)");
        return Ok(());
    }
    let engine = Arc::new(Engine::new(dir)?);
    for workers in [1usize, 2] {
        let cfg = TrainConfig {
            method: Method::GrassWalk,
            steps: 1,
            rank: 16,
            interval: 10,
            workers,
            log_every: 0,
            eval_every: 0,
            ..Default::default()
        };
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        trainer.train_step()?; // warmup/compile
        let n = 10;
        let t0 = Instant::now();
        for _ in 0..n {
            trainer.train_step()?;
        }
        let per = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "train_step e2e (workers={workers})                    \
             {:>8.1}ms/step",
            per * 1e3
        );
    }
    Ok(())
}
