//! Bench: coordinator substrates — ring all-reduce scaling, persistent
//! worker-pool fork-join, loader throughput/backpressure, and the full
//! train-step breakdown (fwd/bwd vs optimizer vs data) that the §Perf
//! L3 pass optimizes against.
//!
//! Comm additions (EXPERIMENTS.md §Net):
//! * **Zero-alloc comm round** — a `GlobalAlloc` wrapper counts heap
//!   allocations process-wide (ring workers included); the steady-state
//!   dense collective round is hard-asserted to perform ZERO (the chunk
//!   buffers ping-pong around the ring instead of the old 2·(N−1)
//!   `to_vec` allocations per worker per round). The low-rank
//!   collective's per-round basis QR remains the documented exception.
//! * **In-process vs tcp-loopback latency** — the same ring schedule
//!   over channel handoffs vs real loopback sockets (frame
//!   encode/decode + CRC + syscalls), the cost model for §Net.
//!
//!   cargo bench --bench coordinator

use std::sync::Arc;
use std::time::{Duration, Instant};

use grasswalk::comm::net::{NetConfig, TcpRingTransport, WorldConfig};
use grasswalk::comm::{
    build_collective, Collective, CommMode, GradLayout, RingTransport,
    Transport,
};
// The process-wide allocation counter lives in the library's counting
// global allocator (grasswalk::util::alloc), which replaced this
// bench's hand-rolled `GlobalAlloc` wrapper. It still counts across
// ALL threads, so the persistent ring workers are covered too.
use grasswalk::util::alloc;

/// N distinct free loopback peer addresses for the tcp-loopback rows.
fn free_peers(n: usize) -> Vec<String> {
    grasswalk::comm::net::launch::free_loopback_peers(n).unwrap()
}
use grasswalk::coordinator::{Ring, TrainConfig, Trainer};
use grasswalk::data::{CorpusConfig, Loader, SyncLoader};
use grasswalk::model::shapes::TINY;
use grasswalk::optim::Method;
use grasswalk::runtime::Engine;
use grasswalk::util::bench::{header, throughput, Bench};
use grasswalk::util::benchgate::Gate;
use grasswalk::util::pool;

fn main() -> anyhow::Result<()> {
    let b = Bench::default();
    let mut gate = Gate::new("coordinator");
    println!("== coordinator substrates ==");
    println!("{}", header());

    // Ring all-reduce scaling in world size and payload.
    for &workers in &[2usize, 4, 8] {
        for &len in &[1 << 12, 1 << 16, 1 << 20] {
            let ring = Ring::new(workers);
            let stats = b.run(
                &format!("ring all-reduce w={workers} len={len}"),
                || {
                    let mut bufs: Vec<Vec<f32>> =
                        (0..workers).map(|_| vec![1.0f32; len]).collect();
                    std::hint::black_box(ring.all_reduce_sum(&mut bufs));
                },
            );
            gate.time(&stats);
            let bytes = 2.0 * (workers - 1) as f64 / workers as f64
                * (len * 4) as f64;
            println!(
                "    -> {:.2} GB/s effective per worker",
                bytes / stats.median.as_secs_f64() / 1e9
            );
        }
    }

    // Persistent ring transport vs the legacy per-call respawn above:
    // same schedule, but threads + links are created once, so the delta
    // is pure spawn overhead removed from every training step.
    for &workers in &[2usize, 4, 8] {
        for &len in &[1usize << 12, 1 << 16, 1 << 20] {
            let transport = RingTransport::new(workers);
            let stats = b.run(
                &format!("persistent ring w={workers} len={len}"),
                || {
                    let mut bufs: Vec<Vec<f32>> =
                        (0..workers).map(|_| vec![1.0f32; len]).collect();
                    std::hint::black_box(
                        transport.all_reduce_sum(&mut bufs).unwrap(),
                    );
                },
            );
            gate.time(&stats);
            let bytes = 2.0 * (workers - 1) as f64 / workers as f64
                * (len * 4) as f64;
            println!(
                "    -> {:.2} GB/s effective per worker (no respawn)",
                bytes / stats.median.as_secs_f64() / 1e9
            );
        }
    }

    // Zero-alloc steady-state comm round (the ring-worker ping-pong
    // satellite): after warmup, NOTHING on the dense collective path
    // allocates — not the coordinator, not the N ring workers. Counted
    // process-wide by the GlobalAlloc wrapper, asserted hard.
    {
        let layout =
            GradLayout::from_shapes(&[vec![256, 64], vec![128]]);
        let mut coll = build_collective(CommMode::Dense, 4, 16, 0);
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| vec![1.0f32; layout.total_floats])
            .collect();
        // Warmup: grows every circulating chunk buffer to capacity.
        for _ in 0..5 {
            coll.all_reduce_mean(&mut bufs, &layout).unwrap();
        }
        let before = alloc::alloc_calls();
        let rounds = 20;
        for _ in 0..rounds {
            coll.all_reduce_mean(&mut bufs, &layout).unwrap();
        }
        let delta = alloc::alloc_calls() - before;
        assert_eq!(
            delta, 0,
            "steady-state dense comm round must perform zero allocations"
        );
        gate.counter("dense comm round allocs (x20 rounds, w=4)", delta);
        println!(
            "zero-alloc comm round: 0 allocations across {rounds} rounds \
             (dense, w=4; lowrank's basis QR is the documented exception)"
        );
    }

    // Overlap + quantization stay on the zero-alloc/zero-spawn path
    // (ISSUE-10): (1) the bucketed, depth-2-pipelined dense round still
    // performs ZERO steady-state allocations — bucket shells and ring
    // chunk buffers ping-pong, the inflight deques are pre-sized;
    // (2) the int8 bucketed+overlapped low-rank round allocates EXACTLY
    // as much as the plain f32 single-shot round, i.e. codec scratch,
    // gather blocks, and pipeline shells add nothing beyond the
    // documented per-round basis-QR floor. Neither regime may touch the
    // thread pool's spawn path in steady state.
    {
        use grasswalk::comm::{BucketPlan, WireCodec};
        let layout = GradLayout::from_shapes(&[
            vec![256, 64],
            vec![128],
            vec![64, 96],
        ]);
        let plan = BucketPlan::from_layout(&layout, 16);
        assert!(plan.len() > 1, "16 KiB must split the bench layout");
        let spawns_before = pool::spawn_count();

        let mut dense = build_collective(CommMode::Dense, 4, 16, 0);
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| vec![1.0f32; layout.total_floats])
            .collect();
        for _ in 0..5 {
            dense
                .all_reduce_mean_bucketed(&mut bufs, &layout, &plan, true)
                .unwrap();
        }
        let before = alloc::alloc_calls();
        let rounds = 20;
        for _ in 0..rounds {
            dense
                .all_reduce_mean_bucketed(&mut bufs, &layout, &plan, true)
                .unwrap();
        }
        let dense_delta = alloc::alloc_calls() - before;
        assert_eq!(
            dense_delta, 0,
            "steady-state bucketed+overlapped dense round must perform \
             zero allocations"
        );
        gate.counter(
            "dense bucketed overlap allocs (x20 rounds, w=4)",
            dense_delta,
        );

        let mut run_lowrank = |codec: WireCodec, bucketed: bool| -> u64 {
            let mut coll = grasswalk::comm::build_collective_with(
                Box::new(RingTransport::new(4)),
                CommMode::LowRank,
                16,
                0,
                codec,
            );
            let mut bufs: Vec<Vec<f32>> = (0..4)
                .map(|_| vec![1.0f32; layout.total_floats])
                .collect();
            let mut round = |bufs: &mut Vec<Vec<f32>>| {
                if bucketed {
                    coll.all_reduce_mean_bucketed(
                        bufs, &layout, &plan, true,
                    )
                    .unwrap();
                } else {
                    coll.all_reduce_mean(bufs, &layout).unwrap();
                }
            };
            for _ in 0..5 {
                round(&mut bufs);
            }
            let before = alloc::alloc_calls();
            for _ in 0..rounds {
                round(&mut bufs);
            }
            alloc::alloc_calls() - before
        };
        let f32_single = run_lowrank(WireCodec::F32, false);
        let int8_piped = run_lowrank(WireCodec::Int8, true);
        assert_eq!(
            int8_piped, f32_single,
            "int8 bucketed+overlapped lowrank round must not allocate \
             beyond the f32 single-shot basis-QR floor"
        );
        gate.counter(
            "lowrank int8 overlap extra allocs (x20 rounds, w=4)",
            int8_piped.saturating_sub(f32_single),
        );

        let spawned = (pool::spawn_count() - spawns_before) as u64;
        assert_eq!(
            spawned, 0,
            "steady-state overlapped/quantized comm must not spawn \
             pool threads"
        );
        gate.counter("overlap+quant comm spawns (all rows)", spawned);
        println!(
            "overlap+quant steady state: dense bucketed {dense_delta} \
             allocs, lowrank int8 piped {int8_piped} vs f32 single-shot \
             {f32_single} (basis QR only), {spawned} spawns"
        );
    }

    // In-process vs tcp-loopback round latency (§Net): the identical
    // ring schedule over channel handoffs vs real loopback sockets with
    // frame encode/decode + CRC. 2 ranks — the coordinator drives rank
    // 0, a companion thread runs rank 1 in lockstep.
    for &len in &[1usize << 12, 1 << 16] {
        let (warmup, rounds) = (5usize, 50usize);
        let inproc = RingTransport::new(2);
        let mut bufs: Vec<Vec<f32>> =
            (0..2).map(|_| vec![1.0f32; len]).collect();
        for _ in 0..warmup {
            inproc.all_reduce_sum(&mut bufs).unwrap();
        }
        let t0 = Instant::now();
        for _ in 0..rounds {
            inproc.all_reduce_sum(&mut bufs).unwrap();
        }
        let inproc_ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;

        let peers = free_peers(2);
        let mk_cfg = |rank: usize, peers: Vec<String>| {
            let mut cfg = WorldConfig::new(
                NetConfig { world: 2, rank, peers },
                0,
                0,
            );
            cfg.connect_timeout = Duration::from_secs(10);
            cfg.io_timeout = Duration::from_secs(10);
            cfg
        };
        let peer_cfg = mk_cfg(1, peers.clone());
        let companion = std::thread::spawn(move || {
            let t = TcpRingTransport::establish(&peer_cfg).unwrap();
            let mut bufs = vec![vec![1.0f32; len]];
            for _ in 0..warmup + rounds {
                t.all_reduce_sum(&mut bufs).unwrap();
            }
        });
        let t = TcpRingTransport::establish(&mk_cfg(0, peers)).unwrap();
        let mut bufs = vec![vec![1.0f32; len]];
        for _ in 0..warmup {
            t.all_reduce_sum(&mut bufs).unwrap();
        }
        let t0 = Instant::now();
        let mut wire = 0usize;
        for _ in 0..rounds {
            wire = t
                .all_reduce_sum(&mut bufs)
                .unwrap()
                .bytes_sent_per_worker;
        }
        let tcp_ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
        companion.join().unwrap();
        gate.time_ns(&format!("ring inproc w=2 len={len}"), inproc_ms * 1e6);
        gate.time_ns(&format!("ring tcp-loopback w=2 len={len}"), tcp_ms * 1e6);
        println!(
            "ring round w=2 len={len}: inproc {inproc_ms:.3} ms vs \
             tcp-loopback {tcp_ms:.3} ms ({wire} wire B/rank/round)"
        );
    }

    // Persistent worker-pool fork-join (the primitive under every GEMM
    // tile, per-matrix optimizer fan-out and per-worker fwd/bwd
    // fan-out): steady-state dispatch reuses long-lived workers, so the
    // spawn delta across every row below must be zero.
    {
        let mut warm = vec![0f32; 1 << 12];
        pool::parallel_chunks(&mut warm, 1 << 8, |_, p| {
            for x in p.iter_mut() {
                *x += 1.0;
            }
        });
        let spawns_before = pool::spawn_count();
        for &len in &[1usize << 12, 1 << 16, 1 << 20] {
            let mut v = vec![0f32; len];
            let chunk = len.div_ceil(pool::threads().max(1)).max(1);
            let stats = b.run(
                &format!(
                    "pool parallel_chunks t={} len={len}",
                    pool::threads()
                ),
                || {
                    pool::parallel_chunks(&mut v, chunk, |_, piece| {
                        for x in piece.iter_mut() {
                            *x += 1.0;
                        }
                    });
                },
            );
            gate.time(&stats);
            println!(
                "    -> {:.2} GB/s touched",
                (len * 4) as f64 / stats.median.as_secs_f64() / 1e9
            );
        }
        let spawned = pool::spawn_count() - spawns_before;
        assert_eq!(
            spawned, 0,
            "steady-state pool dispatch must not spawn threads"
        );
        gate.counter("pool dispatch spawns (all rows)", spawned);
        println!("    -> spawns across all rows: 0 (persistent pool)");
    }

    // Collective regimes on the proxy-model (TINY) gradient layout:
    // dense full exchange vs shared-seed low-rank factors.
    let shapes: Vec<Vec<usize>> =
        TINY.param_shapes().iter().map(|p| p.shape.clone()).collect();
    let layout = GradLayout::from_shapes(&shapes);
    for mode in [CommMode::Dense, CommMode::LowRank] {
        let mut coll = build_collective(mode, 4, 16, 0);
        let mut payload = 0usize;
        let s = b.run(
            &format!("collective {} w=4 (TINY layout)", mode.label()),
            || {
                let mut bufs: Vec<Vec<f32>> = (0..4)
                    .map(|_| vec![1.0f32; layout.total_floats])
                    .collect();
                let stats =
                    coll.all_reduce_mean(&mut bufs, &layout).unwrap();
                payload = stats.bytes_per_worker;
                std::hint::black_box(bufs);
            },
        );
        gate.time(&s);
        println!(
            "    -> {payload} wire bytes/worker/step, {:.1} rounds/s",
            throughput(1, s.median)
        );
    }

    // Loader: sync vs prefetching throughput.
    let cfg = CorpusConfig::default();
    let mut sync = SyncLoader::new(cfg.clone(), 0, 1, 8, 65);
    let s = b.run("loader sync 8x65", || {
        std::hint::black_box(sync.next());
    });
    gate.time(&s);
    println!(
        "    -> {:.0} batches/s",
        throughput(1, s.median)
    );
    let pre = Loader::spawn(cfg, 0, 1, 8, 65, 8);
    // Drain warm queue then measure steady-state.
    for _ in 0..8 {
        let _ = pre.next();
    }
    let s = b.run("loader prefetch 8x65", || {
        std::hint::black_box(pre.next());
    });
    gate.time(&s);
    println!(
        "    -> {:.0} batches/s (hides generation latency)",
        throughput(1, s.median)
    );

    // Full train-step breakdown on the compiled model. Artifact-gated,
    // but the bench gate must run either way, so no early return here.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = Arc::new(Engine::new(dir)?);
        for workers in [1usize, 2] {
            let cfg = TrainConfig {
                method: Method::GrassWalk,
                steps: 1,
                rank: 16,
                interval: 10,
                workers,
                log_every: 0,
                eval_every: 0,
                ..Default::default()
            };
            let mut trainer = Trainer::new(engine.clone(), cfg)?;
            trainer.train_step()?; // warmup/compile
            let n = 10;
            let t0 = Instant::now();
            for _ in 0..n {
                trainer.train_step()?;
            }
            let per = t0.elapsed().as_secs_f64() / n as f64;
            gate.time_ns(
                &format!("train_step e2e workers={workers}"),
                per * 1e9,
            );
            println!(
                "train_step e2e (workers={workers})                    \
                 {:>8.1}ms/step",
                per * 1e3
            );
        }
    } else {
        eprintln!("(skipping train-step rows: run `make artifacts`)");
    }

    if let Err(e) = gate.finish() {
        eprintln!("{e}");
        std::process::exit(1);
    }
    Ok(())
}
