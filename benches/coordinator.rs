//! Bench: coordinator substrates — ring all-reduce scaling, loader
//! throughput/backpressure, and the full train-step breakdown (fwd/bwd vs
//! optimizer vs data) that the §Perf L3 pass optimizes against.
//!
//!   cargo bench --bench coordinator

use std::sync::Arc;
use std::time::Instant;

use grasswalk::coordinator::{Ring, TrainConfig, Trainer};
use grasswalk::data::{CorpusConfig, Loader, SyncLoader};
use grasswalk::optim::Method;
use grasswalk::runtime::Engine;
use grasswalk::util::bench::{header, throughput, Bench};

fn main() -> anyhow::Result<()> {
    let b = Bench::default();
    println!("== coordinator substrates ==");
    println!("{}", header());

    // Ring all-reduce scaling in world size and payload.
    for &workers in &[2usize, 4, 8] {
        for &len in &[1 << 12, 1 << 16, 1 << 20] {
            let ring = Ring::new(workers);
            let stats = b.run(
                &format!("ring all-reduce w={workers} len={len}"),
                || {
                    let mut bufs: Vec<Vec<f32>> =
                        (0..workers).map(|_| vec![1.0f32; len]).collect();
                    std::hint::black_box(ring.all_reduce_sum(&mut bufs));
                },
            );
            let bytes = 2.0 * (workers - 1) as f64 / workers as f64
                * (len * 4) as f64;
            println!(
                "    -> {:.2} GB/s effective per worker",
                bytes / stats.median.as_secs_f64() / 1e9
            );
        }
    }

    // Loader: sync vs prefetching throughput.
    let cfg = CorpusConfig::default();
    let mut sync = SyncLoader::new(cfg.clone(), 0, 1, 8, 65);
    let s = b.run("loader sync 8x65", || {
        std::hint::black_box(sync.next());
    });
    println!(
        "    -> {:.0} batches/s",
        throughput(1, s.median)
    );
    let pre = Loader::spawn(cfg, 0, 1, 8, 65, 8);
    // Drain warm queue then measure steady-state.
    for _ in 0..8 {
        let _ = pre.next();
    }
    let s = b.run("loader prefetch 8x65", || {
        std::hint::black_box(pre.next());
    });
    println!(
        "    -> {:.0} batches/s (hides generation latency)",
        throughput(1, s.median)
    );

    // Full train-step breakdown on the compiled model.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(skipping train-step rows: run `make artifacts`)");
        return Ok(());
    }
    let engine = Arc::new(Engine::new(dir)?);
    for workers in [1usize, 2] {
        let cfg = TrainConfig {
            method: Method::GrassWalk,
            steps: 1,
            rank: 16,
            interval: 10,
            workers,
            log_every: 0,
            eval_every: 0,
            ..Default::default()
        };
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        trainer.train_step()?; // warmup/compile
        let n = 10;
        let t0 = Instant::now();
        for _ in 0..n {
            trainer.train_step()?;
        }
        let per = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "train_step e2e (workers={workers})                    \
             {:>8.1}ms/step",
            per * 1e3
        );
    }
    Ok(())
}
