//! Bench: Table 1 end-to-end — per-step wall time of every method on the
//! compiled proxy model (the paper's wall-time column is a per-step-cost
//! ranking; shape to verify: randomized methods ≈ cheapest, SVD-based
//! slowest, subspace-refresh steps dominating).
//!
//!   cargo bench --bench table1_methods
//! (harness = false: self-contained timing, criterion unavailable offline)

use std::sync::Arc;
use std::time::Instant;

use grasswalk::coordinator::{TrainConfig, Trainer};
use grasswalk::optim::Method;
use grasswalk::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return Ok(());
    }
    let engine = Arc::new(Engine::new(dir)?);
    let steps = std::env::var("BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30usize);

    println!("== table1_methods: {} steps/method, proxy model ==", steps);
    println!(
        "{:<12} {:>12} {:>14} {:>12} {:>12}",
        "method", "total (s)", "per step (ms)", "refresh (ms)", "eval loss"
    );

    let mut rows = Vec::new();
    for method in Method::TABLE1 {
        let cfg = TrainConfig {
            method,
            steps,
            rank: 16,
            interval: 10, // several refreshes inside the bench window
            lr: 1e-2,
            dense_lr: 1e-2,
            eval_every: steps,
            log_every: 0,
            ..Default::default()
        };
        let mut trainer = Trainer::new(engine.clone(), cfg)?;
        // Warmup (compile caches, allocator).
        trainer.train_step()?;

        let mut per_step = Vec::with_capacity(steps);
        for _ in 0..steps {
            let t0 = Instant::now();
            trainer.train_step()?;
            per_step.push(t0.elapsed().as_secs_f64());
        }
        let eval = trainer.eval()?;
        let total: f64 = per_step.iter().sum();
        // Refresh steps are every `interval`; estimate their cost as the
        // mean of the top 1/interval quantile.
        let mut sorted = per_step.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let n_refresh = (steps / 10).max(1);
        let refresh_ms = sorted[..n_refresh].iter().sum::<f64>()
            / n_refresh as f64
            * 1e3;
        println!(
            "{:<12} {:>12.2} {:>14.1} {:>12.1} {:>12.4}",
            method.label(),
            total,
            total / steps as f64 * 1e3,
            refresh_ms,
            eval
        );
        rows.push((method, total / steps as f64));
    }

    // Shape check: the paper's wall-clock story — random-projection
    // methods are at least as cheap per step as the SVD-based ones.
    let per = |m: Method| {
        rows.iter().find(|r| r.0 == m).map(|r| r.1).unwrap()
    };
    println!(
        "\nshape: grassjump <= 1.1x galore per-step: {}",
        per(Method::GrassJump) <= per(Method::GaLore) * 1.1
    );
    Ok(())
}
