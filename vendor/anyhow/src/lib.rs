//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! re-implements the (small) API surface the repository actually uses:
//!
//! * [`Error`] — a message plus an optional boxed source chain,
//! * [`Result<T>`] with the `Error` default,
//! * [`anyhow!`] / [`bail!`] — format-string constructors,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * `?`-conversion from any `std::error::Error + Send + Sync + 'static`.
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent. `{:#}` formatting prints the full cause chain, matching
//! anyhow's alternate Display.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: message + optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error value, preserving it as source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message (the `Context` machinery).
    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        // Flatten: the previous error becomes part of the chain text.
        // We keep the chain as a rendered string tail since `Error`
        // itself is not a `std::error::Error`.
        let mut chained = self.msg;
        if let Some(src) = &self.source {
            let mut cur: Option<&(dyn StdError + 'static)> = Some(src.as_ref());
            while let Some(e) = cur {
                chained.push_str(": ");
                chained.push_str(&e.to_string());
                cur = e.source();
            }
        }
        Error {
            msg: format!("{context}: {chained}"),
            source: None,
        }
    }

    /// Iterate the rendered cause chain (outermost first).
    fn chain_string(&self) -> String {
        let mut out = self.msg.clone();
        if let Some(src) = &self.source {
            let mut cur: Option<&(dyn StdError + 'static)> = Some(src.as_ref());
            while let Some(e) = cur {
                out.push_str(": ");
                out.push_str(&e.to_string());
                cur = e.source();
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow.
            write!(f, "{}", self.chain_string())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `Result::unwrap` and `fn main() -> Result<()>` route through
        // Debug; show the full chain there.
        write!(f, "{}", self.chain_string())
    }
}

// `?` conversion from any standard error. Coherent because `Error`
// itself does not implement `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — alias with the dynamic error default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).wrap(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn context_wraps_and_alternate_prints_chain() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "opening file").unwrap_err();
        let full = format!("{e:#}");
        assert!(full.starts_with("opening file"), "{full}");
        assert!(full.contains("gone"), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} of {}", 1, 2);
        assert_eq!(format!("{e}"), "bad 1 of 2");
        fn f() -> Result<()> {
            bail!("nope {}", 9)
        }
        assert!(format!("{:#}", f().unwrap_err()).contains("nope 9"));
    }
}
